//! Minimal command-line argument parser (the image vendors no clap).
//!
//! Supports the subset the `rtopk` binary needs: a positional subcommand,
//! `--key value`, `--key=value`, boolean `--flag`, and typed extraction
//! with defaults and error messages.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-flag token (the subcommand), if any.
    pub command: Option<String>,
    /// Remaining positional tokens after the subcommand.
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    seen: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

impl Args {
    /// Parse from an iterator of tokens (not including argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> anyhow::Result<Args> {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if rest.is_empty() {
                    anyhow::bail!("bare `--` is not supported");
                }
                if let Some((k, v)) = rest.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` unless the next token is another flag
                    // (then it's a boolean flag).
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            args.flags.insert(rest.to_string(), v);
                        }
                        _ => {
                            args.flags.insert(rest.to_string(), "true".to_string());
                        }
                    }
                }
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> anyhow::Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.seen.borrow_mut().insert(key.to_string());
    }

    /// Raw string value.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.mark(key);
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<String> {
        self.get(key)
            .map(|s| s.to_string())
            .ok_or_else(|| anyhow::anyhow!("missing required flag --{key}"))
    }

    pub fn usize_or(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got {v:?}")),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> anyhow::Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => anyhow::bail!("--{key} expects a boolean, got {v:?}"),
        }
    }

    /// Error on any flag that was provided but never read — catches typos.
    pub fn reject_unknown(&self) -> anyhow::Result<()> {
        let seen = self.seen.borrow();
        let unknown: Vec<_> = self
            .flags
            .keys()
            .filter(|k| !seen.contains(*k))
            .cloned()
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            anyhow::bail!("unknown flag(s): {}", unknown.join(", "))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("train --nodes 5 --ratio 0.99 --federated");
        assert_eq!(a.command.as_deref(), Some("train"));
        assert_eq!(a.usize_or("nodes", 1).unwrap(), 5);
        assert_eq!(a.f64_or("ratio", 0.0).unwrap(), 0.99);
        assert!(a.bool_or("federated", false).unwrap());
    }

    #[test]
    fn equals_form() {
        let a = parse("x --k=32 --name=lm_tiny");
        assert_eq!(a.usize_or("k", 0).unwrap(), 32);
        assert_eq!(a.str_or("name", ""), "lm_tiny");
    }

    #[test]
    fn flag_before_another_flag_is_boolean() {
        let a = parse("x --verbose --k 3");
        assert!(a.bool_or("verbose", false).unwrap());
        assert_eq!(a.usize_or("k", 0).unwrap(), 3);
    }

    #[test]
    fn missing_required_errors() {
        let a = parse("x");
        assert!(a.req_str("model").is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = parse("x");
        assert_eq!(a.usize_or("nodes", 5).unwrap(), 5);
        assert_eq!(a.f64_or("lr", 0.1).unwrap(), 0.1);
    }

    #[test]
    fn bad_types_error() {
        let a = parse("x --k abc");
        assert!(a.usize_or("k", 0).is_err());
    }

    #[test]
    fn reject_unknown_catches_typos() {
        let a = parse("x --nodse 5");
        let _ = a.usize_or("nodes", 1);
        assert!(a.reject_unknown().is_err());
        let b = parse("x --nodes 5");
        let _ = b.usize_or("nodes", 1);
        assert!(b.reject_unknown().is_ok());
    }

    #[test]
    fn pipeline_spec_values_survive_parsing() {
        // Pipeline specs carry ':', ',', '|' and '='; both flag forms must
        // deliver them verbatim (the '=' form splits on the FIRST '=').
        let a = parse("train --pipeline rtopk:r=4k,k=256|bf16|delta");
        assert_eq!(a.get("pipeline"), Some("rtopk:r=4k,k=256|bf16|delta"));
        let b = parse("train --pipeline=topk:k=512|bf16");
        assert_eq!(b.get("pipeline"), Some("topk:k=512|bf16"));
    }

    #[test]
    fn positional_tokens() {
        let a = parse("experiment table1 table2 --quick");
        assert_eq!(a.command.as_deref(), Some("experiment"));
        assert_eq!(a.positional, vec!["table1", "table2"]);
    }
}
