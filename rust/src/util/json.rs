//! Minimal JSON parser + writer (the image vendors no serde_json).
//!
//! Scope: everything `artifacts/manifest.json`, experiment configs and the
//! metrics writers need — objects, arrays, strings (with escapes), numbers,
//! booleans, null. Numbers parse to f64 (plus exact i64 when integral),
//! matching how the manifest uses them.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------------- accessors ----------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object member access that errors with a path-like message.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key {:?} in {:.60?}", key, self))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(x) if x.fract() == 0.0 && x.abs() < 9.0e15 => Some(*x as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|x| usize::try_from(x).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    // ---------------- parsing ----------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---------------- writing ----------------

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, val)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    val.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructor for object literals.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Self {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Self {
        Json::Str(x)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Self {
        Json::Bool(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // BMP only (sufficient for our files); surrogate
                            // pairs map to replacement char.
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // advance one UTF-8 char
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_i64(), Some(1));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\" A"));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"arr":[1,2.5,"s"],"flag":true,"nested":{"x":null}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        assert_eq!(Json::parse(&v.to_pretty()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\":}", "nul", "1 2", "\"unterminated"] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn real_manifest_shape() {
        let text = r#"{
          "models": [{"name": "lm_tiny", "dim": 118016,
            "train": {"file": "lm_tiny.train.hlo.txt",
                      "inputs": [{"shape": [118016], "dtype": "float32"}],
                      "outputs": [{"shape": [], "dtype": "float32"}]}}]
        }"#;
        let v = Json::parse(text).unwrap();
        let m = &v.get("models").unwrap().as_arr().unwrap()[0];
        assert_eq!(m.get("dim").unwrap().as_usize(), Some(118016));
        let ins = m.get("train").unwrap().get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(ins[0].get("shape").unwrap().as_arr().unwrap()[0].as_usize(), Some(118016));
    }

    #[test]
    fn integers_serialized_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }
}
