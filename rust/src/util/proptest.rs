//! Lightweight property-based testing (the image vendors no proptest).
//!
//! [`check`] runs a property over many generated cases with independent,
//! reproducible sub-seeds; on failure it reports the failing case seed so
//! the case replays with `check_seed`. Generation helpers cover the vector
//! shapes the invariant tests need (dense, sparse, adversarial values).

use super::rng::Rng;

/// Number of cases per property (override with RTOPK_PROPTEST_CASES).
pub fn default_cases() -> usize {
    std::env::var("RTOPK_PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// Base seed every property derives its case seeds from. Defaults to a
/// fixed constant; CI also runs the suite under a run-derived base
/// (`RTOPK_PROPTEST_SEED=$GITHUB_RUN_ID`) so each pipeline run explores a
/// fresh region of the input space while staying replayable — the failure
/// message echoes both the base and the case seed.
pub fn base_seed() -> u64 {
    std::env::var("RTOPK_PROPTEST_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xC0FFEE_u64)
}

/// Run `prop` over `cases` generated cases. `prop` gets a fresh seeded RNG
/// per case and returns `Err(reason)` on violation.
pub fn check<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    let base = base_seed();
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property {name:?} failed on case {case} \
                 (base seed {base}, replay with check_seed({seed:#x})): {msg}"
            );
        }
    }
}

/// Replay a single failing case by its reported seed.
pub fn check_seed<F>(name: &str, seed: u64, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property {name:?} failed on replay (seed {seed:#x}): {msg}");
    }
}

/// Assert helper that produces `Result<(), String>` bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

/// A random dense vector with occasionally-adversarial values
/// (zeros, ties, huge/tiny magnitudes, negatives).
pub fn gen_vector(rng: &mut Rng, max_len: usize) -> Vec<f32> {
    let n = 1 + rng.index(max_len);
    let style = rng.index(5);
    (0..n)
        .map(|_| match style {
            0 => rng.normal_f32(0.0, 1.0),
            1 => rng.normal_f32(0.0, 1e-6),                 // tiny magnitudes
            2 => rng.normal_f32(0.0, 1e6),                  // huge magnitudes
            3 => rng.index(5) as f32 - 2.0,               // heavy ties incl. zeros
            _ => {
                if rng.bernoulli(0.8) {
                    0.0                                      // sparse
                } else {
                    rng.normal_f32(0.0, 3.0)
                }
            }
        })
        .collect()
}

/// A (dim, k, r) triple with 1 <= k <= r <= dim.
pub fn gen_kr(rng: &mut Rng, dim: usize) -> (usize, usize) {
    let r = 1 + rng.index(dim);
    let k = 1 + rng.index(r);
    (k, r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("true", 16, |_rng| Ok(()));
    }

    #[test]
    #[should_panic(expected = "failed on case")]
    fn check_reports_failure_with_seed() {
        check("fails-sometimes", 16, |rng| {
            if rng.index(4) == 0 {
                Err("boom".to_string())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn gen_vector_within_bounds() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let v = gen_vector(&mut rng, 100);
            assert!(!v.is_empty() && v.len() <= 100);
        }
    }

    #[test]
    fn gen_kr_ordering() {
        let mut rng = Rng::new(2);
        for _ in 0..200 {
            let dim = 1 + rng.index(1000);
            let (k, r) = gen_kr(&mut rng, dim);
            assert!(1 <= k && k <= r && r <= dim);
        }
    }
}
