//! Support substrates the offline image does not provide as crates.
//!
//! The build environment vendors only the `xla` crate's dependency closure
//! (no tokio / clap / serde / rand / criterion / proptest), so this module
//! implements the slices of each that the system needs:
//!
//! * [`rng`] — xoshiro256++ PRNG (replaces `rand`)
//! * [`json`] — JSON parser/writer (replaces `serde_json`)
//! * [`cli`] — argument parsing (replaces `clap`)
//! * [`bench`] — micro-benchmark harness (replaces `criterion`)
//! * [`proptest`] — property-test driver (replaces `proptest`)
//! * [`chunkpool`] — deterministic scoped-thread chunk pool (replaces `rayon`)

pub mod bench;
pub mod chunkpool;
pub mod cli;
pub mod json;
pub mod proptest;
pub mod rng;
