//! Deterministic scoped-thread chunk pool for hot-path selection scans
//! and the leader/relay aggregation pipeline.
//!
//! The selection kernels (`atopk` filter, magnitude histogram, max-abs)
//! walk the gradient in fixed-size chunks of [`SELECT_CHUNK`] elements.
//! This pool fans those chunks out over a caller-chosen number of scoped
//! threads (`std::thread::scope` — the image vendors no rayon) with one
//! output slot per *chunk*, not per thread, and the caller merges slots
//! in chunk order. Because chunk boundaries are fixed and every chunk
//! writes only its own slot, the merged result is bit-identical for any
//! thread count, including 1.
//!
//! The pool size flows from config (`--select-threads` for the worker
//! selection scans, `--agg-threads` for the leader/relay aggregation
//! pipeline — DESIGN.md §13); round logic must never read ambient
//! machine parallelism (the `rtopk-lint` `determinism-threads` rule
//! enforces this).

/// Fixed chunk width for all parallel selection scans. Mirrors the
/// Pallas prototype's block size; must never depend on thread count.
pub const SELECT_CHUNK: usize = 65_536;

/// Number of [`SELECT_CHUNK`] chunks covering `len` elements.
pub fn num_chunks(len: usize) -> usize {
    len.div_ceil(SELECT_CHUNK)
}

/// A fixed-size worker pool over chunked scans. Holds no OS resources:
/// threads are scoped per call, so the pool is trivially `Copy` and
/// cheap to embed in every compressor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkPool {
    threads: usize,
}

impl Default for ChunkPool {
    fn default() -> Self {
        ChunkPool::serial()
    }
}

impl ChunkPool {
    /// Pool with `threads` workers (clamped to at least 1).
    pub fn new(threads: usize) -> ChunkPool {
        ChunkPool { threads: threads.max(1) }
    }

    /// Single-threaded pool: `run_chunks` degenerates to a plain loop.
    pub fn serial() -> ChunkPool {
        ChunkPool { threads: 1 }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(chunk_index, &mut slot)` for every chunk in `0..nchunks`,
    /// each chunk writing only its own slot. `slots` is grown (never
    /// shrunk) to `nchunks` so steady-state calls are allocation-free;
    /// slot contents are whatever the previous call left — `f` must
    /// fully overwrite or clear its slot.
    ///
    /// Chunks are assigned to threads as contiguous blocks in index
    /// order, but since each chunk's output lands in its own slot the
    /// assignment is unobservable: merging `slots[..nchunks]` in order
    /// yields the same bytes for any thread count.
    pub fn run_chunks<T, F>(&self, nchunks: usize, slots: &mut Vec<T>, f: F)
    where
        T: Send + Default,
        F: Fn(usize, &mut T) + Sync,
    {
        if slots.len() < nchunks {
            slots.resize_with(nchunks, T::default);
        }
        let slots = &mut slots[..nchunks];
        let threads = self.threads.min(nchunks);
        if threads <= 1 {
            for (c, slot) in slots.iter_mut().enumerate() {
                f(c, slot);
            }
            return;
        }
        let base = nchunks / threads;
        let extra = nchunks % threads;
        std::thread::scope(|scope| {
            let f = &f;
            let mut rest = slots;
            let mut start = 0usize;
            for t in 0..threads {
                let len = base + usize::from(t < extra);
                let (head, tail) = std::mem::take(&mut rest).split_at_mut(len);
                rest = tail;
                let first = start;
                scope.spawn(move || {
                    for (j, slot) in head.iter_mut().enumerate() {
                        f(first + j, slot);
                    }
                });
                start += len;
            }
        });
    }

    /// Split `data` into consecutive parts of `width` elements (the last
    /// part may be short) and run `f(part_index, part)` for each. Part
    /// boundaries are fixed by `width` — never by thread count — and every
    /// part is a disjoint `&mut` subslice, so writes cannot race and the
    /// result is bit-identical for any thread count, including 1.
    ///
    /// This is the write-in-place dual of [`Self::run_chunks`]: instead of
    /// merging per-chunk slots afterwards, the caller's buffer IS the
    /// output (parallel scatter into a params/accumulator vector, one
    /// decode slot per frame, …). Parts are assigned to threads as
    /// contiguous blocks in index order, like chunks.
    pub fn run_parts<T, F>(&self, data: &mut [T], width: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        assert!(width > 0, "part width must be positive");
        let nparts = data.len().div_ceil(width);
        let threads = self.threads.min(nparts);
        if threads <= 1 {
            for (p, part) in data.chunks_mut(width).enumerate() {
                f(p, part);
            }
            return;
        }
        let base = nparts / threads;
        let extra = nparts % threads;
        std::thread::scope(|scope| {
            let f = &f;
            let mut rest = data;
            let mut first_part = 0usize;
            for t in 0..threads {
                let parts_here = base + usize::from(t < extra);
                let elems = (parts_here * width).min(rest.len());
                let (head, tail) = std::mem::take(&mut rest).split_at_mut(elems);
                rest = tail;
                let first = first_part;
                scope.spawn(move || {
                    for (j, part) in head.chunks_mut(width).enumerate() {
                        f(first + j, part);
                    }
                });
                first_part += parts_here;
            }
        });
    }

    /// Run `f(i, &mut data[i])` once per element — [`Self::run_parts`]
    /// with width 1, for one-task-per-item fan-outs (e.g. one frame
    /// decode per reusable slot).
    pub fn run_slots<T, F>(&self, data: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        self.run_parts(data, 1, |i, part| f(i, &mut part[0]));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Each chunk records its own index; the merged result must be the
    /// identity permutation for any thread count.
    fn indices_seen(pool: &ChunkPool, nchunks: usize) -> Vec<usize> {
        let mut slots: Vec<usize> = Vec::new();
        pool.run_chunks(nchunks, &mut slots, |c, slot| *slot = c + 1);
        slots[..nchunks].iter().map(|&v| v - 1).collect()
    }

    #[test]
    fn every_chunk_runs_exactly_once_in_slot_order() {
        for threads in [1, 2, 3, 8, 64] {
            let pool = ChunkPool::new(threads);
            for nchunks in [0, 1, 2, 7, 8, 9, 100] {
                let want: Vec<usize> = (0..nchunks).collect();
                assert_eq!(
                    indices_seen(&pool, nchunks),
                    want,
                    "threads={threads} nchunks={nchunks}"
                );
            }
        }
    }

    #[test]
    fn slots_grow_but_never_shrink() {
        let pool = ChunkPool::new(4);
        let mut slots: Vec<u32> = Vec::new();
        pool.run_chunks(10, &mut slots, |c, s| *s = c as u32);
        assert_eq!(slots.len(), 10);
        pool.run_chunks(3, &mut slots, |c, s| *s = 100 + c as u32);
        assert_eq!(slots.len(), 10, "later smaller runs must not shrink slots");
        assert_eq!(&slots[..3], &[100, 101, 102]);
        assert_eq!(&slots[3..], &[3, 4, 5, 6, 7, 8, 9], "untouched slots keep old contents");
    }

    #[test]
    fn thread_count_clamps_to_at_least_one() {
        assert_eq!(ChunkPool::new(0).threads(), 1);
        assert_eq!(ChunkPool::default().threads(), 1);
        assert_eq!(ChunkPool::new(8).threads(), 8);
    }

    #[test]
    fn run_parts_covers_every_element_once_with_fixed_boundaries() {
        // Each part writes `part_index` into its own elements; for any
        // thread count the result must be the same fixed partition.
        for threads in [1, 2, 3, 8, 64] {
            let pool = ChunkPool::new(threads);
            for (len, width) in [(0usize, 3usize), (1, 3), (7, 3), (9, 3), (10, 3), (5, 100)] {
                let mut data = vec![usize::MAX; len];
                pool.run_parts(&mut data, width, |p, part| {
                    assert!(part.len() <= width);
                    for x in part.iter_mut() {
                        *x = p;
                    }
                });
                let want: Vec<usize> = (0..len).map(|i| i / width).collect();
                assert_eq!(data, want, "threads={threads} len={len} width={width}");
            }
        }
    }

    #[test]
    fn run_slots_is_one_task_per_element() {
        for threads in [1, 2, 5] {
            let pool = ChunkPool::new(threads);
            let mut data = vec![0usize; 13];
            pool.run_slots(&mut data, |i, x| *x = i * i);
            let want: Vec<usize> = (0..13).map(|i| i * i).collect();
            assert_eq!(data, want, "threads={threads}");
        }
    }

    #[test]
    fn chunk_math_covers_the_range() {
        assert_eq!(num_chunks(0), 0);
        assert_eq!(num_chunks(1), 1);
        assert_eq!(num_chunks(SELECT_CHUNK), 1);
        assert_eq!(num_chunks(SELECT_CHUNK + 1), 2);
        assert_eq!(num_chunks(10 * SELECT_CHUNK), 10);
    }
}
