//! Deterministic pseudo-random number generation.
//!
//! The offline image vendors no `rand` crate, so the repo ships its own
//! small, well-tested generator: xoshiro256++ seeded through SplitMix64
//! (Blackman & Vigna). Every stochastic component in the system (rTop-k's
//! random subset, data synthesis, the estimation simulator) takes an
//! explicit [`Rng`] so runs are reproducible from a single seed.

/// xoshiro256++ PRNG. Not cryptographic; excellent statistical quality and
/// speed for simulation workloads.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the Box-Muller transform.
    gauss_spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Mix three words into one well-distributed seed (SplitMix64 finalizer
/// over a chained combine). This is the repo-wide convention for deriving
/// stateless per-entity streams — e.g. `(population_seed, client_id,
/// round)` in the federation layer — where forking a shared [`Rng`] would
/// require materializing state per entity. Pure function: same inputs,
/// same seed, on every call and every rerun.
#[inline]
pub fn mix_seed(a: u64, b: u64, c: u64) -> u64 {
    let mut z = a ^ b.wrapping_mul(0xA076_1D64_78BD_642F) ^ c.rotate_left(32);
    z = splitmix64(&mut z);
    let mut z2 = z ^ c.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    splitmix64(&mut z2)
}

impl Rng {
    /// Seed the generator; any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent child stream (for per-node / per-shard RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        // Mix the stream id through splitmix so forks with nearby ids are
        // statistically independent.
        let mut seed = self.next_u64() ^ stream.wrapping_mul(0xA076_1D64_78BD_642F);
        seed = seed.rotate_left(17).wrapping_add(stream);
        Rng::new(seed)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n). Lemire's unbiased multiply-shift method.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (second value cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // u1 in (0,1] to avoid ln(0)
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal(mu, sigma) as f32.
    #[inline]
    pub fn normal_f32(&mut self, mu: f32, sigma: f32) -> f32 {
        (mu as f64 + sigma as f64 * self.normal()) as f32
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) uniformly (Floyd's algorithm).
    /// O(k) expected time and memory; order of the result is randomized.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        // Floyd's: for j in n-k..n, pick t in [0, j]; insert t or j.
        let mut set = std::collections::HashSet::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.index(j + 1);
            let chosen = if set.insert(t) { t } else { j };
            if chosen != t {
                set.insert(j);
            }
            out.push(chosen);
        }
        self.shuffle(&mut out);
        out
    }

    /// Vector of standard normals (f32).
    pub fn normal_vec(&mut self, n: usize, mu: f32, sigma: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32(mu, sigma)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(7);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut r = Rng::new(4);
        let mut counts = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            let expect = n / 10;
            assert!((c as i64 - expect as i64).unsigned_abs() < (expect / 10) as u64, "{counts:?}");
        }
    }

    #[test]
    fn below_one_always_zero() {
        let mut r = Rng::new(5);
        for _ in 0..100 {
            assert_eq!(r.below(1), 0);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(6);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(8);
        for _ in 0..200 {
            let n = 1 + r.index(100);
            let k = r.index(n + 1);
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k, "duplicates in {s:?}");
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn sample_indices_uniform_membership() {
        // Each index should appear with probability k/n.
        let mut r = Rng::new(9);
        let (n, k, trials) = (20usize, 5usize, 40_000usize);
        let mut counts = vec![0usize; n];
        for _ in 0..trials {
            for i in r.sample_indices(n, k) {
                counts[i] += 1;
            }
        }
        let expect = trials * k / n;
        for &c in &counts {
            assert!(
                (c as i64 - expect as i64).unsigned_abs() < (expect / 5) as u64,
                "{counts:?}"
            );
        }
    }

    #[test]
    fn mix_seed_is_pure_and_sensitive_to_every_word() {
        assert_eq!(mix_seed(1, 2, 3), mix_seed(1, 2, 3));
        let base = mix_seed(1, 2, 3);
        assert_ne!(base, mix_seed(0, 2, 3));
        assert_ne!(base, mix_seed(1, 0, 3));
        assert_ne!(base, mix_seed(1, 2, 0));
        // Nearby entity ids must not collide (they seed adjacent clients).
        let mut seen = std::collections::HashSet::new();
        for client in 0..10_000u64 {
            seen.insert(mix_seed(0xD15C0, client, 7));
        }
        assert_eq!(seen.len(), 10_000);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(10);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
