//! Micro-benchmark harness (the image vendors no criterion).
//!
//! Bench targets are `harness = false` binaries that call [`Bench::new`]
//! and register closures with [`Bench::run`]. Output mirrors criterion's
//! essentials: median / mean / p95 wall time per iteration plus derived
//! throughput, printed as aligned rows so `cargo bench` output is directly
//! pasteable into EXPERIMENTS.md.
//!
//! Perf trajectory: [`Bench::write_json`] additionally emits the group's
//! rows as machine-readable `BENCH_<group>.json` (under
//! `RTOPK_BENCH_JSON_DIR`, default `target/bench-json`), so CI can archive
//! one artifact per gate and throughput regressions show up as a diffable
//! time series instead of scrollback archaeology. Externally-timed rows
//! (e.g. full end-to-end rounds measured by the cluster itself) join the
//! same stream through [`Bench::record`].

use std::path::PathBuf;
use std::time::{Duration, Instant};

use super::json::{obj, Json};

/// Optimizer barrier (criterion's `black_box` equivalent).
#[inline]
pub fn bb<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p95_ns: f64,
    /// Optional elements-per-iteration for throughput reporting.
    pub elems: Option<usize>,
    /// Optional wire bytes per iteration (end-to-end rows report the
    /// measured uplink so the JSON trajectory tracks bytes, not just time).
    pub bytes: Option<u64>,
}

impl Stats {
    pub fn throughput_m_elems_s(&self) -> Option<f64> {
        self.elems.map(|e| e as f64 / self.median_ns * 1e3)
    }
}

pub struct Bench {
    pub group: String,
    /// Target per-measurement budget.
    pub budget: Duration,
    pub results: Vec<Stats>,
    /// Quick mode (RTOPK_BENCH_QUICK=1) shrinks budgets ~10x for CI.
    quick: bool,
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:8.1} ns")
    } else if ns < 1e6 {
        format!("{:8.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:8.2} ms", ns / 1e6)
    } else {
        format!("{:8.2} s ", ns / 1e9)
    }
}

impl Bench {
    pub fn new(group: &str) -> Self {
        let quick = std::env::var("RTOPK_BENCH_QUICK").is_ok_and(|v| v == "1");
        println!("\n== bench group: {group} {}==", if quick { "(quick) " } else { "" });
        println!(
            "{:<44} {:>11} {:>11} {:>11} {:>12}",
            "benchmark", "median", "mean", "p95", "throughput"
        );
        Bench {
            group: group.to_string(),
            budget: if quick { Duration::from_millis(120) } else { Duration::from_millis(900) },
            results: Vec::new(),
            quick,
        }
    }

    /// Time `f`, which performs ONE iteration of the workload per call.
    pub fn run<F: FnMut()>(&mut self, name: &str, f: F) -> &Stats {
        self.run_elems(name, None, f)
    }

    /// Time `f` and report throughput as `elems` elements per iteration.
    pub fn run_elems<F: FnMut()>(&mut self, name: &str, elems: Option<usize>, mut f: F) -> &Stats {
        // Warmup: run until ~10% of budget or 3 iterations.
        let warm_budget = self.budget / 10;
        let warm_start = Instant::now();
        let mut warm_iters = 0usize;
        while warm_iters < 3 || warm_start.elapsed() < warm_budget {
            f();
            warm_iters += 1;
            if warm_iters > 1_000_000 {
                break;
            }
        }
        let per_iter = warm_start.elapsed().as_nanos() as f64 / warm_iters as f64;

        // Measurement: collect enough samples to fill the budget, with
        // batching for very fast closures so timer overhead stays < 1%.
        let batch = (100.0 / per_iter.max(1.0)).ceil().max(1.0) as usize;
        let target_samples = if self.quick { 12 } else { 30 };
        let mut samples: Vec<f64> = Vec::with_capacity(target_samples);
        let meas_start = Instant::now();
        while samples.len() < target_samples && meas_start.elapsed() < self.budget {
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples.push(t.elapsed().as_nanos() as f64 / batch as f64);
        }
        if samples.is_empty() {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_nanos() as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = samples[samples.len() / 2];
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let p95_idx = ((samples.len() as f64 * 0.95) as usize).min(min_idx(samples.len()));
        let p95 = samples[p95_idx];
        let stats = Stats {
            name: format!("{}/{name}", self.group),
            iters: samples.len() * batch,
            median_ns: median,
            mean_ns: mean,
            p95_ns: p95,
            elems,
            bytes: None,
        };
        let tput = stats
            .throughput_m_elems_s()
            .map(|t| format!("{t:9.1} Me/s"))
            .unwrap_or_else(|| "-".to_string());
        println!(
            "{:<44} {} {} {} {:>12}",
            stats.name,
            fmt_ns(stats.median_ns),
            fmt_ns(stats.mean_ns),
            fmt_ns(stats.p95_ns),
            tput
        );
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// Register an externally-timed row (one measurement, e.g. a mean
    /// round time reported by the cluster) so it prints like the others
    /// and joins the group's JSON output.
    pub fn record(
        &mut self,
        name: &str,
        median_ns: f64,
        elems: Option<usize>,
        bytes: Option<u64>,
    ) -> &Stats {
        let stats = Stats {
            name: format!("{}/{name}", self.group),
            iters: 1,
            median_ns,
            mean_ns: median_ns,
            p95_ns: median_ns,
            elems,
            bytes,
        };
        let tput = stats
            .throughput_m_elems_s()
            .map(|t| format!("{t:9.1} Me/s"))
            .unwrap_or_else(|| "-".to_string());
        println!(
            "{:<44} {} {} {} {:>12}",
            stats.name,
            fmt_ns(stats.median_ns),
            fmt_ns(stats.mean_ns),
            fmt_ns(stats.p95_ns),
            tput
        );
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// Write every recorded row as `BENCH_<group>.json` under
    /// `RTOPK_BENCH_JSON_DIR` (default `target/bench-json`). Returns the
    /// path so callers can echo it.
    pub fn write_json(&self) -> anyhow::Result<PathBuf> {
        let dir = PathBuf::from(
            std::env::var("RTOPK_BENCH_JSON_DIR")
                .unwrap_or_else(|_| "target/bench-json".to_string()),
        );
        std::fs::create_dir_all(&dir)?;
        let rows: Vec<Json> = self
            .results
            .iter()
            .map(|s| {
                let mut fields = vec![
                    ("name", Json::from(s.name.as_str())),
                    ("iters", Json::from(s.iters)),
                    ("median_ns", Json::from(s.median_ns)),
                    ("mean_ns", Json::from(s.mean_ns)),
                    ("p95_ns", Json::from(s.p95_ns)),
                ];
                if let Some(e) = s.elems {
                    fields.push(("elems", Json::from(e)));
                }
                if let Some(t) = s.throughput_m_elems_s() {
                    fields.push(("throughput_m_elems_s", Json::from(t)));
                }
                if let Some(b) = s.bytes {
                    fields.push(("bytes", Json::from(b as usize)));
                }
                obj(fields)
            })
            .collect();
        let path = dir.join(format!("BENCH_{}.json", self.group));
        std::fs::write(
            &path,
            obj(vec![
                ("group", Json::from(self.group.as_str())),
                ("quick", Json::from(self.quick)),
                ("results", Json::Arr(rows)),
            ])
            .to_pretty(),
        )?;
        Ok(path)
    }
}

// small helper: clamp index
fn min_idx(len: usize) -> usize {
    len - 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_sane() {
        std::env::set_var("RTOPK_BENCH_QUICK", "1");
        let mut b = Bench::new("selftest");
        let mut acc = 0u64;
        let s = b.run("noop-ish", || {
            acc = bb(acc.wrapping_add(1));
        });
        assert!(s.median_ns > 0.0 && s.median_ns < 1e6);
        assert!(s.iters > 0);
    }

    #[test]
    fn record_and_write_json_round_trip() {
        std::env::set_var("RTOPK_BENCH_QUICK", "1");
        let dir = std::env::temp_dir().join("rtopk-bench-json-test");
        std::env::set_var("RTOPK_BENCH_JSON_DIR", &dir);
        let mut b = Bench::new("selftest3");
        b.record("e2e_round", 1.5e6, Some(4096), Some(1234));
        let path = b.write_json().unwrap();
        let v = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(v.get("group").unwrap().as_str(), Some("selftest3"));
        let rows = v.get("results").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("name").unwrap().as_str(), Some("selftest3/e2e_round"));
        assert_eq!(rows[0].get("bytes").unwrap().as_usize(), Some(1234));
        assert!(rows[0].get("median_ns").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn throughput_derived_from_elems() {
        std::env::set_var("RTOPK_BENCH_QUICK", "1");
        let mut b = Bench::new("selftest2");
        let v = vec![1.0f32; 1024];
        let s = b
            .run_elems("sum1k", Some(1024), || {
                bb(v.iter().sum::<f32>());
            })
            .clone();
        assert!(s.throughput_m_elems_s().unwrap() > 0.0);
    }
}
