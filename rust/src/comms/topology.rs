//! Aggregation topology: how the coordinator's nodes are wired.
//!
//! The paper's Algorithm 1 assumes a star — every worker talks straight to
//! the centralized processor, so the root's ingress grows as O(n) encoded
//! frames per round. Shi et al. (gTop-k) observe that top-k unions stay
//! small enough that hierarchical reduction preserves accuracy while
//! cutting root traffic; and because our decoded payloads are *mergeable*
//! sparse vectors, aggregation can happen at intermediate relays.
//! [`Topology`] makes the wiring a config value:
//!
//! * [`Topology::Star`] — the classic shape: `n` leaves, no relays.
//! * [`Topology::Tree`] — a `fanout`-ary tree of `depth` edge levels.
//!   Leaves (workers) sit at the bottom; every internal node is a *relay*
//!   that gathers its children's updates, k-way merges them in the sparse
//!   domain, re-encodes the union, and forwards ONE frame upward. Root
//!   ingress drops from n frames to at most `fanout` frames per round.
//!
//! **Star pin**: `tree:fanout=n,depth=1` produces zero relays — the plan's
//! root children are exactly the n workers — so it is bit-identical to
//! `star` by construction (same links, same ids, same engine path). The
//! integration suite asserts this over both transports, params and byte
//! counters included.
//!
//! Construction is deterministic: worker ids are assigned to contiguous
//! in-order leaf ranges, split as evenly as possible into at most `fanout`
//! chunks per level (larger chunks first). Every chunk gets a relay while
//! more than one edge level remains, so the tree shape depends only on
//! `(n, fanout, depth)` — never on timing or arrival order.

use std::ops::Range;

/// A node reference inside a [`TreePlan`]: either a leaf worker (global
/// worker id) or a relay (index into [`TreePlan::relays`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeRef {
    Worker(usize),
    Relay(usize),
}

/// One relay in the plan.
#[derive(Debug, Clone)]
pub struct RelaySpec {
    /// Tree level: 1 = direct child of the root.
    pub level: usize,
    /// The contiguous range of worker ids this relay's subtree covers.
    pub leaves: Range<usize>,
    /// Direct children, in leaf order.
    pub children: Vec<NodeRef>,
}

/// A fully resolved tree: which relays exist, who parents whom.
#[derive(Debug, Clone)]
pub struct TreePlan {
    pub n_workers: usize,
    /// Relays in creation order (parents before children). Relay `r`'s
    /// global node id is `n_workers + r`.
    pub relays: Vec<RelaySpec>,
    /// The root's direct children, in leaf order.
    pub root_children: Vec<NodeRef>,
}

impl TreePlan {
    /// Global node id of a [`NodeRef`] (workers `0..n`, relays `n..n+R`).
    pub fn node_id(&self, r: NodeRef) -> usize {
        match r {
            NodeRef::Worker(w) => w,
            NodeRef::Relay(i) => self.n_workers + i,
        }
    }

    /// Number of leaf workers under a direct child of some node.
    pub fn leaves_of(&self, r: NodeRef) -> usize {
        match r {
            NodeRef::Worker(_) => 1,
            NodeRef::Relay(i) => self.relays[i].leaves.len(),
        }
    }
}

/// Human-readable node label for transport/error attribution: the peer a
/// multi-hop failure message names.
pub fn node_label(id: usize, n_workers: usize) -> String {
    if id < n_workers {
        format!("worker-{id}")
    } else {
        format!("relay-{}", id - n_workers)
    }
}

/// How the cluster's nodes are wired (CLI `--topology`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Topology {
    /// Every worker talks straight to the root (the default).
    #[default]
    Star,
    /// `fanout`-ary tree with `depth` edge levels (`None` = the smallest
    /// depth whose capacity `fanout^depth` covers the worker count).
    Tree { fanout: usize, depth: Option<usize> },
}

/// Upper bound on explicit tree depth — deeper trees than this are
/// invariably a spec typo, and the bound keeps `fanout^depth` comfortably
/// inside u64 for every fanout ≥ 2.
pub const MAX_TREE_DEPTH: usize = 8;

impl Topology {
    /// Parse a `--topology` spec: `star` | `tree:fanout=<F>[,depth=<D>]`.
    pub fn parse(s: &str) -> anyhow::Result<Topology> {
        let t = s.trim().to_ascii_lowercase();
        if t == "star" {
            return Ok(Topology::Star);
        }
        if let Some(rest) = t.strip_prefix("tree:") {
            let mut fanout: Option<usize> = None;
            let mut depth: Option<usize> = None;
            for kv in rest.split(',') {
                let (k, v) = kv.split_once('=').ok_or_else(|| {
                    anyhow::anyhow!("topology spec: expected key=value, got {kv:?}")
                })?;
                match k.trim() {
                    "fanout" => {
                        fanout = Some(v.trim().parse().map_err(|_| {
                            anyhow::anyhow!("topology spec: fanout expects an integer, got {v:?}")
                        })?);
                    }
                    "depth" => {
                        depth = Some(v.trim().parse().map_err(|_| {
                            anyhow::anyhow!("topology spec: depth expects an integer, got {v:?}")
                        })?);
                    }
                    other => {
                        anyhow::bail!("topology spec: unknown key {other:?} (fanout, depth)")
                    }
                }
            }
            let fanout = fanout
                .ok_or_else(|| anyhow::anyhow!("tree topology needs fanout=<count>: {s:?}"))?;
            return Ok(Topology::Tree { fanout, depth });
        }
        anyhow::bail!("unknown topology {s:?} (star | tree:fanout=<F>[,depth=<D>])")
    }

    /// Round-trippable spec string.
    pub fn label(&self) -> String {
        match self {
            Topology::Star => "star".to_string(),
            Topology::Tree { fanout, depth: None } => format!("tree:fanout={fanout}"),
            Topology::Tree { fanout, depth: Some(d) } => {
                format!("tree:fanout={fanout},depth={d}")
            }
        }
    }

    pub fn is_star(&self) -> bool {
        matches!(self, Topology::Star)
    }

    /// The depth this topology resolves to for `n` workers (explicit, or
    /// the smallest `d ≥ 1` with `fanout^d ≥ n`).
    pub fn resolved_depth(&self, n: usize) -> anyhow::Result<usize> {
        match *self {
            Topology::Star => Ok(1),
            Topology::Tree { fanout, depth } => {
                anyhow::ensure!(fanout >= 1, "tree fanout must be >= 1, got {fanout}");
                let d = match depth {
                    Some(d) => {
                        anyhow::ensure!(
                            (1..=MAX_TREE_DEPTH).contains(&d),
                            "tree depth must be in [1, {MAX_TREE_DEPTH}], got {d}"
                        );
                        d
                    }
                    None => {
                        let mut d = 1usize;
                        while capacity(fanout, d) < n as u128 {
                            d += 1;
                            anyhow::ensure!(
                                d <= MAX_TREE_DEPTH,
                                "fanout {fanout} cannot cover {n} workers within depth \
                                 {MAX_TREE_DEPTH}"
                            );
                        }
                        d
                    }
                };
                anyhow::ensure!(
                    capacity(fanout, d) >= n as u128,
                    "tree fanout={fanout},depth={d} holds at most {} leaves, need {n}",
                    capacity(fanout, d)
                );
                Ok(d)
            }
        }
    }

    pub fn validate(&self, n: usize) -> anyhow::Result<()> {
        anyhow::ensure!(n >= 1, "topology needs >= 1 worker");
        self.resolved_depth(n).map(|_| ())
    }

    /// Build the deterministic tree plan for `n` workers. A star (and a
    /// depth-1 tree, which is the same shape) yields zero relays with the
    /// workers as the root's direct children.
    pub fn plan(&self, n: usize) -> anyhow::Result<TreePlan> {
        let depth = self.resolved_depth(n)?;
        let fanout = match *self {
            Topology::Star => n.max(1),
            Topology::Tree { fanout, .. } => fanout,
        };
        let mut plan = TreePlan { n_workers: n, relays: Vec::new(), root_children: Vec::new() };
        plan.root_children = build_children(0..n, fanout, depth, 1, &mut plan.relays);
        Ok(plan)
    }

    /// Global node ids of the root's direct children, in leaf order — what
    /// the engine's gather phase indexes its inbox by.
    pub fn root_child_ids(&self, n: usize) -> anyhow::Result<Vec<usize>> {
        let plan = self.plan(n)?;
        Ok(plan.root_children.iter().map(|&c| plan.node_id(c)).collect())
    }
}

fn capacity(fanout: usize, depth: usize) -> u128 {
    (fanout as u128).saturating_pow(depth as u32)
}

/// Split a contiguous worker range into one child list, recursing while
/// more than one edge level remains. Chunk sizes are as even as possible
/// with the larger chunks first, so the shape is a pure function of the
/// inputs.
fn build_children(
    range: Range<usize>,
    fanout: usize,
    levels_left: usize,
    level: usize,
    relays: &mut Vec<RelaySpec>,
) -> Vec<NodeRef> {
    let n = range.len();
    if levels_left <= 1 {
        return range.map(NodeRef::Worker).collect();
    }
    let chunks = fanout.min(n).max(1);
    let base = n / chunks;
    let rem = n % chunks;
    let mut children = Vec::with_capacity(chunks);
    let mut start = range.start;
    for c in 0..chunks {
        let len = base + usize::from(c < rem);
        let chunk = start..start + len;
        start += len;
        let idx = relays.len();
        // reserve the slot first so parents precede children in the list
        relays.push(RelaySpec { level, leaves: chunk.clone(), children: Vec::new() });
        let sub = build_children(chunk, fanout, levels_left - 1, level + 1, relays);
        relays[idx].children = sub;
        children.push(NodeRef::Relay(idx));
    }
    children
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips() {
        assert_eq!(Topology::parse("star").unwrap(), Topology::Star);
        let t = Topology::parse("tree:fanout=4,depth=2").unwrap();
        assert_eq!(t, Topology::Tree { fanout: 4, depth: Some(2) });
        assert_eq!(Topology::parse(&t.label()).unwrap(), t);
        let auto = Topology::parse("tree:fanout=8").unwrap();
        assert_eq!(auto, Topology::Tree { fanout: 8, depth: None });
        assert_eq!(Topology::parse(&auto.label()).unwrap(), auto);
        assert!(Topology::parse("tree").is_err());
        assert!(Topology::parse("tree:depth=2").is_err());
        assert!(Topology::parse("tree:fanout=x").is_err());
        assert!(Topology::parse("tree:fanout=2,k=1").is_err());
        assert!(Topology::parse("ring").is_err());
    }

    #[test]
    fn depth_resolution_and_validation() {
        let t = Topology::Tree { fanout: 4, depth: None };
        assert_eq!(t.resolved_depth(1).unwrap(), 1);
        assert_eq!(t.resolved_depth(4).unwrap(), 1);
        assert_eq!(t.resolved_depth(5).unwrap(), 2);
        assert_eq!(t.resolved_depth(16).unwrap(), 2);
        assert_eq!(t.resolved_depth(17).unwrap(), 3);
        // explicit depth too small for n is a config error, not a hang
        let small = Topology::Tree { fanout: 2, depth: Some(2) };
        assert!(small.validate(5).is_err());
        assert!(small.validate(4).is_ok());
        // fanout 1 only ever covers one worker
        let unary = Topology::Tree { fanout: 1, depth: None };
        assert!(unary.validate(1).is_ok());
        assert!(unary.validate(2).is_err());
        assert!(Topology::Tree { fanout: 0, depth: None }.validate(1).is_err());
        assert!(Topology::Tree { fanout: 2, depth: Some(0) }.validate(1).is_err());
        assert!(Topology::Tree { fanout: 2, depth: Some(99) }.validate(1).is_err());
    }

    #[test]
    fn star_and_depth1_tree_have_identical_plans() {
        // The bit-identity pin starts here: zero relays, workers as the
        // root's direct children, in id order.
        let star = Topology::Star.plan(5).unwrap();
        let tree = Topology::Tree { fanout: 5, depth: Some(1) }.plan(5).unwrap();
        for plan in [&star, &tree] {
            assert!(plan.relays.is_empty());
            assert_eq!(
                plan.root_children,
                (0..5).map(NodeRef::Worker).collect::<Vec<_>>()
            );
        }
        assert_eq!(
            Topology::Star.root_child_ids(3).unwrap(),
            vec![0, 1, 2]
        );
    }

    #[test]
    fn balanced_two_level_tree() {
        // n=16, fanout=4, depth=2: 4 relays of 4 contiguous workers each.
        let t = Topology::Tree { fanout: 4, depth: Some(2) };
        let plan = t.plan(16).unwrap();
        assert_eq!(plan.relays.len(), 4);
        assert_eq!(plan.root_children.len(), 4);
        for (r, spec) in plan.relays.iter().enumerate() {
            assert_eq!(spec.level, 1);
            assert_eq!(spec.leaves, r * 4..r * 4 + 4);
            assert_eq!(
                spec.children,
                (r * 4..r * 4 + 4).map(NodeRef::Worker).collect::<Vec<_>>()
            );
            assert_eq!(plan.node_id(NodeRef::Relay(r)), 16 + r);
            assert_eq!(plan.leaves_of(NodeRef::Relay(r)), 4);
        }
        assert_eq!(t.root_child_ids(16).unwrap(), vec![16, 17, 18, 19]);
    }

    #[test]
    fn uneven_split_keeps_contiguous_in_order_ranges() {
        // n=5, fanout=4, depth=2: chunks [2,1,1,1], larger first, all
        // contiguous and in worker-id order.
        let plan = Topology::Tree { fanout: 4, depth: Some(2) }.plan(5).unwrap();
        assert_eq!(plan.relays.len(), 4);
        let ranges: Vec<_> = plan.relays.iter().map(|r| r.leaves.clone()).collect();
        assert_eq!(ranges, vec![0..2, 2..3, 3..4, 4..5]);
        // coverage is gap-free and ordered
        let mut next = 0;
        for r in &ranges {
            assert_eq!(r.start, next);
            next = r.end;
        }
        assert_eq!(next, 5);
    }

    #[test]
    fn three_level_tree_nests_relays() {
        // n=8, fanout=2, depth=3: root -> 2 relays -> 4 relays -> 8 workers.
        let plan = Topology::Tree { fanout: 2, depth: Some(3) }.plan(8).unwrap();
        assert_eq!(plan.root_children.len(), 2);
        assert_eq!(plan.relays.len(), 6);
        let top: Vec<usize> = plan
            .root_children
            .iter()
            .map(|&c| match c {
                NodeRef::Relay(i) => i,
                NodeRef::Worker(w) => panic!("unexpected leaf {w} at the root"),
            })
            .collect();
        for &i in &top {
            assert_eq!(plan.relays[i].level, 1);
            assert_eq!(plan.relays[i].leaves.len(), 4);
            for &c in &plan.relays[i].children {
                match c {
                    NodeRef::Relay(j) => {
                        assert_eq!(plan.relays[j].level, 2);
                        assert_eq!(plan.relays[j].leaves.len(), 2);
                        assert!(plan.relays[j]
                            .children
                            .iter()
                            .all(|&c| matches!(c, NodeRef::Worker(_))));
                    }
                    NodeRef::Worker(w) => panic!("unexpected leaf {w} at level 1"),
                }
            }
        }
    }

    #[test]
    fn node_labels_name_role_and_index() {
        assert_eq!(node_label(3, 8), "worker-3");
        assert_eq!(node_label(8, 8), "relay-0");
        assert_eq!(node_label(10, 8), "relay-2");
    }
}
