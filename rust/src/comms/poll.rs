//! Minimal `poll(2)` FFI shim for the evented transport.
//!
//! The build environment is offline (no mio/tokio/libc crates), so the
//! reactor talks to the kernel through this one extern declaration. The
//! struct layout matches `struct pollfd` from `<poll.h>` on every Linux
//! ABI this project targets: `int fd; short events; short revents;`.

use std::io;

/// One kernel readiness registration, `#[repr(C)]`-identical to
/// `struct pollfd`.
#[repr(C)]
#[derive(Debug, Clone, Copy)]
pub struct PollFd {
    pub fd: i32,
    pub events: i16,
    pub revents: i16,
}

/// Readable (or a peer hang-up is pending behind buffered data).
pub const POLLIN: i16 = 0x001;
/// Writable without blocking.
pub const POLLOUT: i16 = 0x004;
/// Error condition (always polled, never requested).
pub const POLLERR: i16 = 0x008;
/// Peer hung up (always polled, never requested).
pub const POLLHUP: i16 = 0x010;

extern "C" {
    fn poll(fds: *mut PollFd, nfds: u64, timeout: i32) -> i32;
}

/// Block until at least one registered fd is ready (or `timeout_ms`
/// elapses; `-1` = wait forever). Returns the number of ready fds; the
/// kernel writes readiness into each entry's `revents`. Retries on
/// `EINTR`, so callers never see a spurious signal-interrupted error.
pub fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> io::Result<usize> {
    loop {
        // SAFETY: `fds` is a valid, exclusively borrowed slice of
        // repr(C) pollfd structs; the kernel writes only within it.
        let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as u64, timeout_ms) };
        if rc >= 0 {
            return Ok(rc as usize);
        }
        let err = io::Error::last_os_error();
        if err.kind() == io::ErrorKind::Interrupted {
            continue;
        }
        return Err(err);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::os::fd::AsRawFd;
    use std::os::unix::net::UnixStream;

    #[test]
    fn reports_writable_then_readable() {
        let (a, mut b) = UnixStream::pair().unwrap();
        let mut fds = [PollFd { fd: a.as_raw_fd(), events: POLLIN | POLLOUT, revents: 0 }];
        let n = poll_fds(&mut fds, 1000).unwrap();
        assert_eq!(n, 1);
        assert_ne!(fds[0].revents & POLLOUT, 0, "fresh socket must be writable");
        assert_eq!(fds[0].revents & POLLIN, 0, "nothing to read yet");

        b.write_all(&[42]).unwrap();
        let mut fds = [PollFd { fd: a.as_raw_fd(), events: POLLIN, revents: 0 }];
        let n = poll_fds(&mut fds, 1000).unwrap();
        assert_eq!(n, 1);
        assert_ne!(fds[0].revents & POLLIN, 0, "pending byte must report readable");
    }

    #[test]
    fn timeout_returns_zero_ready() {
        let (a, _b) = UnixStream::pair().unwrap();
        let mut fds = [PollFd { fd: a.as_raw_fd(), events: POLLIN, revents: 0 }];
        assert_eq!(poll_fds(&mut fds, 10).unwrap(), 0);
    }
}
