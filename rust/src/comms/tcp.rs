//! TCP transport: the same star/tree topologies over real sockets.
//!
//! Used for multi-process deployments (`rtopk train --transport tcp ...`)
//! and to validate that the simulated transport's accounting matches what
//! a real network stack would carry. Framing: 1-byte message tag, u64
//! round, then tag-specific payload with u32 length prefixes.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use super::topology::{node_label, NodeRef, TreePlan};
use super::transport::Message;
use crate::compress::codec;

const TAG_PARAMS: u8 = 1;
const TAG_UPDATE: u8 = 2;
const TAG_SHUTDOWN: u8 = 3;
const TAG_DELTA: u8 = 4;
const TAG_RESYNC: u8 = 5;
const TAG_FAILED: u8 = 6;

/// Upper bound on any single frame's variable-length body. A corrupt or
/// hostile length prefix must fail fast with an error instead of driving a
/// multi-gigabyte allocation before the first payload byte is read
/// (`u32::MAX * 4` for a params frame). 1 GiB comfortably covers every
/// model dimension this system targets (d ≤ 2^28 f32 params).
pub const MAX_FRAME_BYTES: usize = 1 << 30;

/// Validate a u32 length prefix scaled to its in-memory byte cost.
fn checked_frame_len(len: u32, elem_bytes: usize, what: &str) -> anyhow::Result<usize> {
    let bytes = (len as usize)
        .checked_mul(elem_bytes)
        .ok_or_else(|| anyhow::anyhow!("{what} frame length overflows ({len} elems)"))?;
    anyhow::ensure!(
        bytes <= MAX_FRAME_BYTES,
        "{what} frame of {bytes} bytes exceeds the {MAX_FRAME_BYTES}-byte bound"
    );
    Ok(len as usize)
}

/// Encode-side mirror of [`checked_frame_len`]: an element count must fit
/// the u32 length prefix AND the decode-side [`MAX_FRAME_BYTES`] bound, or
/// the writer would silently wrap the prefix and desync the stream for
/// every frame that follows.
fn checked_encode_len(len: usize, elem_bytes: usize, what: &str) -> anyhow::Result<u32> {
    checked_encode_len_bounded(len, elem_bytes, MAX_FRAME_BYTES, what)
}

/// [`checked_encode_len`] against an explicit bound (unit tests exercise
/// the rejection paths without gigabyte allocations).
fn checked_encode_len_bounded(
    len: usize,
    elem_bytes: usize,
    bound: usize,
    what: &str,
) -> anyhow::Result<u32> {
    let bytes = len
        .checked_mul(elem_bytes)
        .ok_or_else(|| anyhow::anyhow!("{what} frame length overflows ({len} elems)"))?;
    anyhow::ensure!(
        bytes <= bound,
        "{what} frame of {bytes} bytes exceeds the {bound}-byte encode bound"
    );
    u32::try_from(len).map_err(|_| anyhow::anyhow!("{what} frame length {len} overflows u32"))
}

/// Encode-side validation of a node id into its u32 wire field — ids are
/// `usize` in memory, and an unchecked narrowing would alias two nodes.
fn checked_node_id(id: usize, what: &str) -> anyhow::Result<u32> {
    u32::try_from(id).map_err(|_| anyhow::anyhow!("{what} node id {id} overflows the u32 wire field"))
}

/// Serialize a message to its wire frame. Every length and node id is
/// validated before it is narrowed into its u32 wire field (mirroring the
/// decode-side `checked_frame_len` bound): an unchecked `as u32` here once
/// wrapped oversized payloads silently and desynced the stream for every
/// frame after them.
pub fn write_message<W: Write>(w: &mut W, msg: &Message) -> anyhow::Result<()> {
    match msg {
        Message::Params { round, data } => {
            let len = checked_encode_len(data.len(), 4, "params")?;
            w.write_all(&[TAG_PARAMS])?;
            w.write_all(&round.to_le_bytes())?;
            w.write_all(&len.to_le_bytes())?;
            // bulk little-endian f32s
            let mut buf = Vec::with_capacity(data.len() * 4);
            for &x in data {
                buf.extend_from_slice(&x.to_le_bytes());
            }
            w.write_all(&buf)?;
        }
        Message::SparseUpdate {
            round,
            worker,
            payload,
            loss,
            examples,
            mem_norm,
            participants,
        } => {
            let wk = checked_node_id(*worker, "update")?;
            let len = checked_encode_len(payload.len(), 1, "update")?;
            w.write_all(&[TAG_UPDATE])?;
            w.write_all(&round.to_le_bytes())?;
            w.write_all(&wk.to_le_bytes())?;
            w.write_all(&loss.to_le_bytes())?;
            w.write_all(&examples.to_le_bytes())?;
            w.write_all(&mem_norm.to_le_bytes())?;
            w.write_all(&participants.to_le_bytes())?;
            w.write_all(&len.to_le_bytes())?;
            w.write_all(payload)?;
        }
        Message::ParamsDelta { round, payload } => {
            let len = checked_encode_len(payload.len(), 1, "delta")?;
            w.write_all(&[TAG_DELTA])?;
            w.write_all(&round.to_le_bytes())?;
            w.write_all(&len.to_le_bytes())?;
            w.write_all(payload)?;
        }
        Message::ResyncRequest { worker } => {
            let wk = checked_node_id(*worker, "resync")?;
            w.write_all(&[TAG_RESYNC])?;
            w.write_all(&0u64.to_le_bytes())?;
            w.write_all(&wk.to_le_bytes())?;
        }
        Message::WorkerFailed { worker } => {
            let wk = checked_node_id(*worker, "failed")?;
            w.write_all(&[TAG_FAILED])?;
            w.write_all(&0u64.to_le_bytes())?;
            w.write_all(&wk.to_le_bytes())?;
        }
        Message::Shutdown => {
            w.write_all(&[TAG_SHUTDOWN])?;
            w.write_all(&0u64.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

/// Serialize a message into an owned frame buffer (the evented transport's
/// per-link queues hold whole frames with partial-write cursors).
pub(super) fn encode_frame(msg: &Message) -> anyhow::Result<Vec<u8>> {
    let mut buf = Vec::new();
    write_message(&mut buf, msg)?;
    Ok(buf)
}

/// Header bytes for a `ParamsDelta` frame whose body is an `Arc<[u8]>`
/// shared across links: tag, round, validated length. The evented
/// transport writes this 13-byte header followed by the shared body — one
/// encode, N cursors, zero per-link copies.
pub(super) fn encode_delta_header(round: u64, body_len: usize) -> anyhow::Result<[u8; 13]> {
    let len = checked_encode_len(body_len, 1, "delta")?;
    let mut h = [0u8; 13];
    h[0] = TAG_DELTA;
    h[1..9].copy_from_slice(&round.to_le_bytes());
    h[9..13].copy_from_slice(&len.to_le_bytes());
    Ok(h)
}

/// Incremental framing for the evented reader: given the bytes buffered so
/// far, return the total frame size once the header is complete
/// (`Ok(None)` = need more bytes; `Err` = corrupt tag or hostile length,
/// fail the link now). Validation matches [`read_message`] exactly, so a
/// frame this accepts always decodes past its header.
pub(super) fn scan_frame_len(buf: &[u8]) -> anyhow::Result<Option<usize>> {
    let Some(&tag) = buf.first() else { return Ok(None) };
    match tag {
        TAG_SHUTDOWN => Ok(Some(9)),
        TAG_RESYNC | TAG_FAILED => Ok(Some(13)),
        TAG_PARAMS => scan_len_prefixed(buf, 9, 4, "params"),
        TAG_DELTA => scan_len_prefixed(buf, 9, 1, "delta"),
        TAG_UPDATE => scan_len_prefixed(buf, 33, 1, "update"),
        t => anyhow::bail!("unknown message tag {t}"),
    }
}

/// Frame size for a tag whose u32 element count sits at `len_at`, scaled
/// by `elem_bytes`, with the [`checked_frame_len`] bound applied before
/// the size is trusted.
fn scan_len_prefixed(
    buf: &[u8],
    len_at: usize,
    elem_bytes: usize,
    what: &str,
) -> anyhow::Result<Option<usize>> {
    let Some(end) = len_at.checked_add(4) else { return Ok(None) };
    let Some(len_bytes) = buf.get(len_at..end) else { return Ok(None) };
    let raw = match <[u8; 4]>::try_from(len_bytes) {
        Ok(b) => u32::from_le_bytes(b),
        Err(_) => return Ok(None),
    };
    let len = checked_frame_len(raw, elem_bytes, what)?;
    let Some(body) = len.checked_mul(elem_bytes) else { return Ok(None) };
    let Some(total) = end.checked_add(body) else { return Ok(None) };
    Ok(Some(total))
}

/// Read one message frame.
pub fn read_message<R: Read>(r: &mut R) -> anyhow::Result<Message> {
    let mut tag = [0u8; 1];
    r.read_exact(&mut tag)?;
    let [tag] = tag;
    let mut round_b = [0u8; 8];
    r.read_exact(&mut round_b)?;
    let round = u64::from_le_bytes(round_b);
    match tag {
        TAG_PARAMS => {
            let mut len_b = [0u8; 4];
            r.read_exact(&mut len_b)?;
            let len = checked_frame_len(u32::from_le_bytes(len_b), 4, "params")?;
            let mut buf = vec![0u8; len * 4];
            r.read_exact(&mut buf)?;
            let data = buf
                .chunks_exact(4)
                .map(|c| codec::read_f32_le(c, 0))
                .collect::<Result<Vec<f32>, _>>()
                .map_err(|e| anyhow::anyhow!("params frame: {e}"))?;
            Ok(Message::Params { round, data })
        }
        TAG_UPDATE => {
            let mut w_b = [0u8; 4];
            r.read_exact(&mut w_b)?;
            let worker = u32::from_le_bytes(w_b) as usize;
            let mut l_b = [0u8; 4];
            r.read_exact(&mut l_b)?;
            let loss = f32::from_le_bytes(l_b);
            let mut e_b = [0u8; 8];
            r.read_exact(&mut e_b)?;
            let examples = u64::from_le_bytes(e_b);
            let mut mn_b = [0u8; 4];
            r.read_exact(&mut mn_b)?;
            let mem_norm = f32::from_le_bytes(mn_b);
            let mut p_b = [0u8; 4];
            r.read_exact(&mut p_b)?;
            let participants = u32::from_le_bytes(p_b);
            let mut len_b = [0u8; 4];
            r.read_exact(&mut len_b)?;
            let len = checked_frame_len(u32::from_le_bytes(len_b), 1, "update")?;
            let mut payload = vec![0u8; len];
            r.read_exact(&mut payload)?;
            Ok(Message::SparseUpdate {
                round,
                worker,
                payload,
                loss,
                examples,
                mem_norm,
                participants,
            })
        }
        TAG_DELTA => {
            let mut len_b = [0u8; 4];
            r.read_exact(&mut len_b)?;
            let len = checked_frame_len(u32::from_le_bytes(len_b), 1, "delta")?;
            let mut payload = vec![0u8; len];
            r.read_exact(&mut payload)?;
            Ok(Message::ParamsDelta { round, payload: payload.into() })
        }
        TAG_RESYNC => {
            let mut w_b = [0u8; 4];
            r.read_exact(&mut w_b)?;
            Ok(Message::ResyncRequest { worker: u32::from_le_bytes(w_b) as usize })
        }
        TAG_FAILED => {
            let mut w_b = [0u8; 4];
            r.read_exact(&mut w_b)?;
            Ok(Message::WorkerFailed { worker: u32::from_le_bytes(w_b) as usize })
        }
        TAG_SHUTDOWN => Ok(Message::Shutdown),
        t => anyhow::bail!("unknown message tag {t}"),
    }
}

/// How long an accepted connection gets to send its 4-byte id hello before
/// the accept loop gives up on it. Generous for loopback and LAN; the
/// point is that a peer which connects and then stalls can no longer wedge
/// cluster startup forever.
pub const HELLO_TIMEOUT: Duration = Duration::from_secs(10);

/// Parent side: bind, accept `n` child connections, return their streams
/// in child-node-id order (children send their global node id as a 4-byte
/// hello).
pub fn accept_workers(listener: &TcpListener, n: usize) -> anyhow::Result<Vec<TcpStream>> {
    accept_workers_timeout(listener, n, HELLO_TIMEOUT)
}

/// [`accept_workers`] with an explicit hello deadline (tests shrink it).
/// The read timeout applies ONLY to the hello — it is cleared before the
/// stream is returned, so bridged links keep their normal blocking reads.
pub fn accept_workers_timeout(
    listener: &TcpListener,
    n: usize,
    hello: Duration,
) -> anyhow::Result<Vec<TcpStream>> {
    let mut slots: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
    for accepted in 0..n {
        let (mut stream, peer) = listener.accept()?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(hello))?;
        let mut id_b = [0u8; 4];
        stream.read_exact(&mut id_b).map_err(|e| {
            anyhow::anyhow!(
                "peer {peer} sent no id hello within {hello:?} \
                 (accept slot {accepted} of {n}): {e}"
            )
        })?;
        stream.set_read_timeout(None)?;
        let id = u32::from_le_bytes(id_b) as usize;
        anyhow::ensure!(id < n, "node id {id} out of range");
        anyhow::ensure!(slots[id].is_none(), "duplicate node id {id}");
        slots[id] = Some(stream);
    }
    Ok(slots.into_iter().map(|s| s.unwrap()).collect())
}

/// Child side: connect and say hello with our node id.
pub fn connect_worker(addr: &str, id: usize) -> anyhow::Result<TcpStream> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    stream.write_all(&checked_node_id(id, "hello")?.to_le_bytes())?;
    Ok(stream)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip_all_variants() {
        let msgs = vec![
            Message::Params { round: 7, data: vec![1.0, -2.5, 3.25] },
            Message::SparseUpdate {
                round: 8,
                worker: 3,
                payload: vec![1, 2, 3, 4, 5],
                loss: 0.25,
                examples: 128,
                mem_norm: 1.5,
                participants: 4,
            },
            Message::ParamsDelta { round: 9, payload: vec![9u8, 8, 7].into() },
            Message::ResyncRequest { worker: 2 },
            Message::WorkerFailed { worker: 1 },
            Message::Shutdown,
        ];
        for msg in msgs {
            let mut buf = Vec::new();
            write_message(&mut buf, &msg).unwrap();
            let back = read_message(&mut &buf[..]).unwrap();
            assert_eq!(back, msg);
        }
    }

    #[test]
    fn corrupt_length_prefix_fails_without_allocating() {
        // A params frame claiming u32::MAX elements would try to allocate
        // 16 GiB before reading a single payload byte; the bound must
        // reject it (and any > MAX_FRAME_BYTES claim) up front.
        for (tag, len) in [
            (TAG_PARAMS, u32::MAX),
            (TAG_PARAMS, (MAX_FRAME_BYTES / 4 + 1) as u32),
            (TAG_UPDATE, u32::MAX),
            (TAG_DELTA, (MAX_FRAME_BYTES + 1) as u32),
        ] {
            let mut buf = Vec::new();
            buf.push(tag);
            buf.extend_from_slice(&0u64.to_le_bytes());
            if tag == TAG_UPDATE {
                // worker + loss + examples + mem_norm + participants come
                // before the len
                buf.extend_from_slice(&0u32.to_le_bytes());
                buf.extend_from_slice(&0f32.to_le_bytes());
                buf.extend_from_slice(&0u64.to_le_bytes());
                buf.extend_from_slice(&0f32.to_le_bytes());
                buf.extend_from_slice(&1u32.to_le_bytes());
            }
            buf.extend_from_slice(&len.to_le_bytes());
            let err = read_message(&mut &buf[..]);
            assert!(err.is_err(), "tag {tag} len {len} must be rejected");
        }
        // A frame at a sane length with a truncated body errors too (EOF),
        // after allocating only its bounded size.
        let mut buf = Vec::new();
        buf.push(TAG_DELTA);
        buf.extend_from_slice(&0u64.to_le_bytes());
        buf.extend_from_slice(&64u32.to_le_bytes());
        buf.extend_from_slice(&[0u8; 3]);
        assert!(read_message(&mut &buf[..]).is_err());
    }

    #[test]
    fn oversized_encode_is_rejected_not_wrapped() {
        // Regression: these fields were narrowed with unchecked `as u32`
        // casts — an oversized worker id or payload length wrapped
        // silently and desynced the stream for every frame after it. The
        // encode side must refuse instead.
        let big = 1usize << 40;
        for msg in [
            Message::ResyncRequest { worker: big },
            Message::WorkerFailed { worker: big },
            Message::SparseUpdate {
                round: 1,
                worker: big,
                payload: vec![1u8; 2],
                loss: 0.0,
                examples: 1,
                mem_norm: 0.0,
                participants: 1,
            },
        ] {
            let mut buf = Vec::new();
            assert!(write_message(&mut buf, &msg).is_err(), "{msg:?} must be rejected");
        }
    }

    #[test]
    fn encode_len_bound_mirrors_decode_bound() {
        assert_eq!(checked_encode_len_bounded(3, 4, 12, "t").unwrap(), 3);
        assert!(checked_encode_len_bounded(4, 4, 12, "t").is_err());
        assert!(checked_encode_len_bounded(usize::MAX, 4, 12, "t").is_err());
        // a count that fits the byte bound but not the u32 prefix is
        // still rejected
        assert!(checked_encode_len_bounded(1usize << 33, 0, 12, "t").is_err());
    }

    #[test]
    fn scan_frame_len_matches_encoded_frames() {
        let msgs = vec![
            Message::Params { round: 7, data: vec![1.0, -2.5, 3.25] },
            Message::SparseUpdate {
                round: 8,
                worker: 3,
                payload: vec![1, 2, 3, 4, 5],
                loss: 0.25,
                examples: 128,
                mem_norm: 1.5,
                participants: 4,
            },
            Message::ParamsDelta { round: 9, payload: vec![9u8, 8, 7].into() },
            Message::ResyncRequest { worker: 2 },
            Message::WorkerFailed { worker: 1 },
            Message::Shutdown,
        ];
        for msg in msgs {
            let buf = encode_frame(&msg).unwrap();
            // every incomplete prefix either asks for more bytes or
            // already knows the exact total — never a wrong answer
            for cut in 0..buf.len() {
                if let Some(total) = scan_frame_len(&buf[..cut]).unwrap() {
                    assert_eq!(total, buf.len(), "{msg:?} at cut {cut}");
                }
            }
            assert_eq!(scan_frame_len(&buf).unwrap(), Some(buf.len()));
            // a following frame's bytes don't change the answer
            let mut two = buf.clone();
            two.extend_from_slice(&buf);
            assert_eq!(scan_frame_len(&two).unwrap(), Some(buf.len()));
        }
        // corrupt tag fails the link immediately
        assert!(scan_frame_len(&[0xFF, 0, 0]).is_err());
        // hostile length prefix fails before any allocation
        let mut buf = vec![TAG_DELTA];
        buf.extend_from_slice(&0u64.to_le_bytes());
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(scan_frame_len(&buf).is_err());
    }

    #[test]
    fn delta_header_matches_write_message() {
        let payload = vec![5u8; 17];
        let framed = encode_frame(&Message::ParamsDelta { round: 42, payload: payload.clone().into() })
            .unwrap();
        let header = encode_delta_header(42, payload.len()).unwrap();
        assert_eq!(&framed[..13], &header[..]);
        assert_eq!(&framed[13..], &payload[..]);
        assert!(encode_delta_header(1, MAX_FRAME_BYTES + 1).is_err());
    }

    #[test]
    fn stalled_hello_times_out_naming_the_slot() {
        // Regression: accept_workers blocked indefinitely in read_exact on
        // the 4-byte hello — one client that connects and never
        // identifies wedged cluster startup forever.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        // The connect completes via the listen backlog; then stall.
        let _stall = TcpStream::connect(addr).unwrap();
        let err = accept_workers_timeout(&listener, 1, Duration::from_millis(200))
            .expect_err("stalled hello must not hang");
        let msg = format!("{err:#}");
        assert!(msg.contains("hello") && msg.contains("slot 0"), "{msg}");
    }

    #[test]
    fn loopback_star() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let n = 3;
        let handles: Vec<_> = (0..n)
            .map(|id| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut s = connect_worker(&addr, id).unwrap();
                    let msg = read_message(&mut s).unwrap();
                    assert!(matches!(msg, Message::Params { round: 1, .. }));
                    write_message(
                        &mut s,
                        &Message::SparseUpdate {
                            round: 1,
                            worker: id,
                            payload: vec![id as u8; 4],
                            loss: 0.0,
                            examples: 1,
                            mem_norm: 0.5,
                            participants: 1,
                        },
                    )
                    .unwrap();
                })
            })
            .collect();
        let mut streams = accept_workers(&listener, n).unwrap();
        for s in streams.iter_mut() {
            write_message(s, &Message::Params { round: 1, data: vec![0.5; 8] }).unwrap();
        }
        let mut seen = std::collections::HashSet::new();
        for s in streams.iter_mut() {
            match read_message(s).unwrap() {
                Message::SparseUpdate { worker, payload, .. } => {
                    assert_eq!(payload, vec![worker as u8; 4]);
                    seen.insert(worker);
                }
                _ => panic!("unexpected"),
            }
        }
        assert_eq!(seen.len(), n);
        for h in handles {
            h.join().unwrap();
        }
    }
}

// ---------------------------------------------------------------------------
// TCP-bridged topologies: the coordinator's channel wiring carried over
// real loopback sockets (one forwarding thread pair per direction per
// link). Used by `rtopk train --transport tcp` and the
// transport-equivalence integration tests — unicast byte counters then
// reflect what the kernel's TCP stack actually carried. The one deliberate
// exception is the shared broadcast frame (`Message::ParamsDelta`): the
// point-to-point bridge replicates it per socket, but it is still recorded
// ONCE on the broadcasting node's `bcast_stats` — the loopback replication
// is an artifact of bridging a broadcast onto unicast sockets, and the
// accounting models the single encode-once frame a broadcast/multicast
// domain would carry per hop (keeping the two transports' measured bytes
// identical, which the equivalence tests assert).
// ---------------------------------------------------------------------------

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;

use super::transport::{
    CountedSender, LeaderEndpoints, LinkStats, RelayEndpoints, WorkerEndpoints,
};

/// A child's face of one bridged edge. Untapped builders bridge every
/// child; the `*_tapped` builders leave designated slots as the raw
/// unsupervised socket so fault-injection tests can drive the wire
/// directly (half-close it, send a corrupt tag, die mid-frame) while the
/// parent side stays fully bridged and supervised.
pub enum ChildSide {
    Bridged(WorkerEndpoints),
    Raw(TcpStream),
}

impl ChildSide {
    fn bridged(self) -> WorkerEndpoints {
        match self {
            ChildSide::Bridged(w) => w,
            ChildSide::Raw(_) => unreachable!("untapped builders bridge every child"),
        }
    }
}

/// Parent half of one bridged edge: the parent→socket writer plus the
/// SUPERVISED socket→parent-inbox reader. Supervision is the fix for the
/// silent-death deadlock: a reader that hits EOF or a decode error we did
/// not cause (by sending `Shutdown` ourselves) injects
/// `Message::WorkerFailed { worker: child_id }` into the parent inbox —
/// mirroring the in-process worker drop-guard protocol — so a full-sync
/// gather aborts naming the dead hop instead of blocking forever on a
/// channel its healthy siblings keep alive.
fn bridge_parent_side(
    parent_sock: TcpStream,
    parent_up_tx: Sender<Message>,
    child_id: usize,
    n_workers: usize,
    down: Arc<LinkStats>,
) -> anyhow::Result<CountedSender> {
    let (dl_tx, dl_rx) = channel::<Message>();
    let mut sock_w = parent_sock.try_clone()?;
    // `closing` is set BEFORE the Shutdown frame can reach the wire, so by
    // the time the child reacts (closes its socket → our reader sees EOF)
    // the reader already knows the teardown is ours.
    let closing = Arc::new(AtomicBool::new(false));
    let closing_w = closing.clone();
    std::thread::spawn(move || {
        while let Ok(msg) = dl_rx.recv() {
            let quit = matches!(msg, Message::Shutdown);
            if quit {
                closing_w.store(true, Ordering::SeqCst);
            }
            if write_message(&mut sock_w, &msg).is_err() || quit {
                return;
            }
        }
    });
    let mut sock_r = parent_sock;
    std::thread::spawn(move || loop {
        match read_message(&mut sock_r) {
            Ok(msg) => {
                if parent_up_tx.send(msg).is_err() {
                    return;
                }
            }
            Err(_) => {
                if !closing.load(Ordering::SeqCst) {
                    let _ = parent_up_tx.send(Message::WorkerFailed { worker: child_id });
                }
                return;
            }
        }
    });
    Ok(CountedSender::new(dl_tx, down, &node_label(child_id, n_workers)))
}

/// Child half of one bridged edge: socket→child-inbox reader (quits after
/// forwarding `Shutdown`) plus child-outbox→socket writer.
fn bridge_child_side(
    child_sock: TcpStream,
    child_id: usize,
    parent_label: &str,
    up: Arc<LinkStats>,
) -> anyhow::Result<WorkerEndpoints> {
    let (wk_tx, wk_rx) = channel::<Message>();
    let mut wsock_r = child_sock.try_clone()?;
    std::thread::spawn(move || {
        while let Ok(msg) = read_message(&mut wsock_r) {
            let quit = matches!(msg, Message::Shutdown);
            if wk_tx.send(msg).is_err() || quit {
                return;
            }
        }
    });
    let (wo_tx, wo_rx) = channel::<Message>();
    let mut wsock_w = child_sock;
    std::thread::spawn(move || {
        while let Ok(msg) = wo_rx.recv() {
            if write_message(&mut wsock_w, &msg).is_err() {
                return;
            }
        }
    });
    Ok(WorkerEndpoints {
        id: child_id,
        from_leader: wk_rx,
        to_leader: CountedSender::new(wo_tx, up, parent_label),
    })
}

/// Accept + connect one socket pair per non-root node and return them in
/// node-id order: `(parent_side[i], child_side[i])` for node `i`.
pub(super) fn socket_pairs(total_nodes: usize) -> anyhow::Result<Vec<(TcpStream, TcpStream)>> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    // Children connect from background threads while the parent accepts.
    let connectors: Vec<_> = (0..total_nodes)
        .map(|id| {
            let addr = addr.clone();
            std::thread::spawn(move || connect_worker(&addr, id))
        })
        .collect();
    let parent_streams = accept_workers(&listener, total_nodes)?;
    let child_streams: Vec<TcpStream> = connectors
        .into_iter()
        .map(|h| h.join().expect("connector thread panicked"))
        .collect::<anyhow::Result<_>>()?;
    Ok(parent_streams.into_iter().zip(child_streams).collect())
}

/// Wire one parent over already-paired sockets for its children. Child
/// slots listed in `taps` stay unbridged (their raw socket is returned for
/// a fault-injection test to drive); their parent side is bridged and
/// supervised like any other link.
fn tcp_node(
    parent_label: &str,
    children: Vec<(usize, (TcpStream, TcpStream))>,
    n_workers: usize,
    taps: &[usize],
) -> anyhow::Result<(LeaderEndpoints, Vec<ChildSide>)> {
    let (up_tx, up_rx) = channel::<Message>();
    let mut to_workers = Vec::with_capacity(children.len());
    let mut child_sides = Vec::with_capacity(children.len());
    let mut down_stats = Vec::with_capacity(children.len());
    let mut up_stats = Vec::with_capacity(children.len());
    let mut child_ids = Vec::with_capacity(children.len());
    for (id, (parent_sock, child_sock)) in children {
        let down = Arc::new(LinkStats::default());
        let up = Arc::new(LinkStats::default());
        let tx = bridge_parent_side(parent_sock, up_tx.clone(), id, n_workers, down.clone())?;
        let side = if taps.contains(&id) {
            ChildSide::Raw(child_sock)
        } else {
            ChildSide::Bridged(bridge_child_side(child_sock, id, parent_label, up.clone())?)
        };
        to_workers.push(tx);
        down_stats.push(down);
        up_stats.push(up);
        child_sides.push(side);
        child_ids.push(id);
    }
    Ok((
        LeaderEndpoints {
            to_workers,
            from_workers: up_rx,
            child_ids,
            down_stats,
            up_stats,
            bcast_stats: Arc::new(LinkStats::default()),
        },
        child_sides,
    ))
}

/// Build a star topology over loopback TCP. Drop-in replacement for
/// [`super::transport::star`].
pub fn tcp_star(n: usize) -> anyhow::Result<(LeaderEndpoints, Vec<WorkerEndpoints>)> {
    let (leader, sides) = tcp_star_tapped(n, &[])?;
    Ok((leader, sides.into_iter().map(ChildSide::bridged).collect()))
}

/// [`tcp_star`] with designated worker slots left as raw sockets for
/// fault-injection tests.
pub fn tcp_star_tapped(
    n: usize,
    taps: &[usize],
) -> anyhow::Result<(LeaderEndpoints, Vec<ChildSide>)> {
    let pairs = socket_pairs(n)?;
    tcp_node("root", (0..n).zip(pairs).collect(), n, taps)
}

/// Build a tree topology over loopback TCP. Drop-in replacement for
/// [`super::transport::tree`]: every parent↔child edge is one socket pair,
/// so per-level byte counters reflect what each hop actually carried. The
/// slot-placement mirrors `transport::tree` line for line on purpose —
/// the two wirings must stay structurally identical (the transport
/// equivalence tests pin them against each other), and the duplication is
/// cheaper than a builder generic over fallible socket wiring.
pub fn tcp_tree(
    plan: &TreePlan,
) -> anyhow::Result<(LeaderEndpoints, Vec<RelayEndpoints>, Vec<WorkerEndpoints>)> {
    let (leader, relays, workers, raw) = tcp_tree_tapped(plan, &[])?;
    debug_assert!(raw.is_empty());
    let workers = workers
        .into_iter()
        .map(|w| w.expect("every worker has a parent link"))
        .collect();
    Ok((leader, relays, workers))
}

/// [`tcp_tree`] with designated WORKER leaves left as raw sockets: the
/// worker vector holds `None` at tapped slots and the raw `(worker_id,
/// socket)` pairs come back in the final element. Taps must name workers,
/// not relays.
#[allow(clippy::type_complexity)]
pub fn tcp_tree_tapped(
    plan: &TreePlan,
    taps: &[usize],
) -> anyhow::Result<(
    LeaderEndpoints,
    Vec<RelayEndpoints>,
    Vec<Option<WorkerEndpoints>>,
    Vec<(usize, TcpStream)>,
)> {
    let n = plan.n_workers;
    let total = n + plan.relays.len();
    let mut pairs: Vec<Option<(TcpStream, TcpStream)>> =
        socket_pairs(total)?.into_iter().map(Some).collect();
    let mut take = |ids: &[usize]| -> Vec<(usize, (TcpStream, TcpStream))> {
        ids.iter()
            .map(|&id| (id, pairs[id].take().expect("each node has exactly one parent")))
            .collect()
    };

    let mut worker_slots: Vec<Option<WorkerEndpoints>> = (0..n).map(|_| None).collect();
    let mut up_slots: Vec<Option<WorkerEndpoints>> =
        (0..plan.relays.len()).map(|_| None).collect();
    let mut down_slots: Vec<Option<LeaderEndpoints>> =
        (0..plan.relays.len()).map(|_| None).collect();
    let mut raw: Vec<(usize, TcpStream)> = Vec::new();

    let mut place = |children: &[NodeRef],
                     sides: Vec<ChildSide>,
                     worker_slots: &mut Vec<Option<WorkerEndpoints>>,
                     up_slots: &mut Vec<Option<WorkerEndpoints>>| {
        for (&child, side) in children.iter().zip(sides) {
            match (child, side) {
                (NodeRef::Worker(w), ChildSide::Bridged(s)) => worker_slots[w] = Some(s),
                (NodeRef::Worker(w), ChildSide::Raw(sock)) => raw.push((w, sock)),
                (NodeRef::Relay(r), ChildSide::Bridged(s)) => up_slots[r] = Some(s),
                (NodeRef::Relay(_), ChildSide::Raw(_)) => {
                    unreachable!("taps name leaf workers, never relays")
                }
            }
        }
    };

    let root_ids: Vec<usize> = plan.root_children.iter().map(|&c| plan.node_id(c)).collect();
    let (leader, sides) = tcp_node("root", take(&root_ids), n, taps)?;
    place(&plan.root_children, sides, &mut worker_slots, &mut up_slots);
    for (r, spec) in plan.relays.iter().enumerate() {
        let ids: Vec<usize> = spec.children.iter().map(|&c| plan.node_id(c)).collect();
        let (down, sides) = tcp_node(&node_label(n + r, n), take(&ids), n, taps)?;
        down_slots[r] = Some(down);
        place(&spec.children, sides, &mut worker_slots, &mut up_slots);
    }

    let relays: Vec<RelayEndpoints> = plan
        .relays
        .iter()
        .enumerate()
        .map(|(r, spec)| RelayEndpoints {
            id: n + r,
            level: spec.level,
            n_leaves: spec.leaves.len(),
            child_leaves: spec.children.iter().map(|&c| plan.leaves_of(c)).collect(),
            up: up_slots[r].take().expect("every relay has a parent link"),
            down: down_slots[r].take().expect("every relay has child links"),
        })
        .collect();
    Ok((leader, relays, worker_slots, raw))
}

#[cfg(test)]
mod bridge_tests {
    use super::super::topology::Topology;
    use super::*;

    #[test]
    fn tcp_bridge_supports_recv_timeout() {
        // The quorum gather's drain deadline must work over the TCP wire
        // exactly like in-process: the bridge forwards socket reads into
        // the leader's channel, so recv_timeout observes them.
        let (leader, workers) = tcp_star(1).unwrap();
        assert!(leader
            .recv_timeout(std::time::Duration::from_millis(5))
            .unwrap()
            .is_none());
        workers[0]
            .to_leader
            .send(Message::ResyncRequest { worker: 0 })
            .unwrap();
        match leader
            .recv_timeout(std::time::Duration::from_millis(2000))
            .unwrap()
        {
            Some(Message::ResyncRequest { worker: 0 }) => {}
            other => panic!("unexpected {other:?}"),
        }
        for tx in &leader.to_workers {
            tx.send(Message::Shutdown).unwrap();
        }
    }

    #[test]
    fn dead_child_socket_injects_worker_failed() {
        // Regression (silent-death deadlock): pre-fix, the parent's
        // socket→inbox reader exited silently on a mid-stream decode
        // error, and a full-sync gather then blocked forever because the
        // healthy siblings kept the shared channel alive. The supervised
        // reader must surface the dead hop as WorkerFailed.
        let (leader, sides) = tcp_star_tapped(2, &[1]).unwrap();
        let mut healthy = None;
        let mut raw = None;
        for (id, side) in sides.into_iter().enumerate() {
            match side {
                ChildSide::Bridged(w) => healthy = Some(w),
                ChildSide::Raw(s) => {
                    assert_eq!(id, 1);
                    raw = Some(s);
                }
            }
        }
        let healthy = healthy.unwrap();
        let mut raw = raw.unwrap();
        // Corrupt tag mid-stream; keep the socket open so the failure is
        // a decode error, not EOF.
        raw.write_all(&[0xFF; 16]).unwrap();
        let deadline = std::time::Duration::from_secs(10);
        match leader.recv_timeout(deadline).unwrap() {
            Some(Message::WorkerFailed { worker: 1 }) => {}
            other => panic!("expected WorkerFailed for worker 1, got {other:?}"),
        }
        // The healthy sibling's link is unaffected.
        healthy.to_leader.send(Message::ResyncRequest { worker: 0 }).unwrap();
        match leader.recv_timeout(deadline).unwrap() {
            Some(Message::ResyncRequest { worker: 0 }) => {}
            other => panic!("unexpected {other:?}"),
        }
        for tx in &leader.to_workers {
            let _ = tx.send(Message::Shutdown);
        }
    }

    #[test]
    fn clean_shutdown_is_not_reported_as_failure() {
        let (leader, workers) = tcp_star(1).unwrap();
        leader.to_workers[0].send(Message::Shutdown).unwrap();
        let w = workers.into_iter().next().unwrap();
        assert!(matches!(w.from_leader.recv().unwrap(), Message::Shutdown));
        drop(w); // closes the child socket — the parent reader sees EOF
        // A teardown we initiated must NOT be reported as a failure: the
        // inbox either stays silent or simply disconnects.
        match leader.recv_timeout(std::time::Duration::from_millis(500)) {
            Ok(Some(msg)) => panic!("clean shutdown surfaced {msg:?}"),
            Ok(None) | Err(_) => {}
        }
    }

    #[test]
    fn tcp_star_roundtrip() {
        let (leader, workers) = tcp_star(2).unwrap();
        let handles: Vec<_> = workers
            .into_iter()
            .map(|w| {
                std::thread::spawn(move || loop {
                    match w.from_leader.recv() {
                        Ok(Message::Params { round, data }) => {
                            w.to_leader
                                .send(Message::SparseUpdate {
                                    round,
                                    worker: w.id,
                                    payload: vec![w.id as u8; 3],
                                    loss: data[0],
                                    examples: 1,
                                    mem_norm: 0.0,
                                    participants: 1,
                                })
                                .unwrap();
                        }
                        _ => return,
                    }
                })
            })
            .collect();
        for round in 0..3u64 {
            for tx in &leader.to_workers {
                tx.send(Message::Params { round, data: vec![round as f32; 4] }).unwrap();
            }
            for _ in 0..2 {
                match leader.from_workers.recv().unwrap() {
                    Message::SparseUpdate { round: r, loss, .. } => {
                        assert_eq!(r, round);
                        assert_eq!(loss, round as f32);
                    }
                    _ => panic!("unexpected"),
                }
            }
        }
        for tx in &leader.to_workers {
            tx.send(Message::Shutdown).unwrap();
        }
        for h in handles {
            h.join().unwrap();
        }
        // counters recorded traffic
        assert!(leader.down_stats[0].snapshot().1 > 0);
        assert!(leader.up_stats[0].snapshot().1 > 0);
    }

    #[test]
    fn tcp_tree_carries_every_hop() {
        // n=4, fanout=2, depth=2 over sockets: forward a frame down both
        // hops and an update up both hops, checking per-hop counters.
        let plan = Topology::Tree { fanout: 2, depth: Some(2) }.plan(4).unwrap();
        let (leader, relays, workers) = tcp_tree(&plan).unwrap();
        assert_eq!(leader.child_ids, vec![4, 5]);
        assert_eq!(relays.len(), 2);

        leader.to_workers[0]
            .send(Message::Params { round: 1, data: vec![2.0; 4] })
            .unwrap();
        let got = relays[0].up.from_leader.recv().unwrap();
        assert!(matches!(&got, Message::Params { round: 1, .. }));
        relays[0].down.to_workers[0].send(got).unwrap();
        match workers[0].from_leader.recv().unwrap() {
            Message::Params { round: 1, data } => assert_eq!(data, vec![2.0; 4]),
            other => panic!("unexpected {other:?}"),
        }
        workers[0]
            .to_leader
            .send(Message::SparseUpdate {
                round: 1,
                worker: 0,
                payload: vec![7u8; 5],
                loss: 0.0,
                examples: 1,
                mem_norm: 0.0,
                participants: 1,
            })
            .unwrap();
        match relays[0].down.from_workers.recv().unwrap() {
            Message::SparseUpdate { worker: 0, participants: 1, .. } => {}
            other => panic!("unexpected {other:?}"),
        }
        relays[0]
            .up
            .to_leader
            .send(Message::SparseUpdate {
                round: 1,
                worker: 4,
                payload: vec![7u8; 8],
                loss: 0.0,
                examples: 2,
                mem_norm: 0.0,
                participants: 2,
            })
            .unwrap();
        match leader.from_workers.recv().unwrap() {
            Message::SparseUpdate { worker: 4, participants: 2, .. } => {}
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(leader.down_stats[0].snapshot(), (1, 16));
        assert_eq!(relays[0].down.down_stats[0].snapshot(), (1, 16));
        assert_eq!(relays[0].down.up_stats[0].snapshot(), (1, 5));
        assert_eq!(leader.up_stats[0].snapshot(), (1, 8));

        // clean shutdown down both levels
        for tx in &leader.to_workers {
            tx.send(Message::Shutdown).unwrap();
        }
        for r in &relays {
            assert!(matches!(r.up.from_leader.recv().unwrap(), Message::Shutdown));
            for tx in &r.down.to_workers {
                tx.send(Message::Shutdown).unwrap();
            }
        }
        for w in &workers {
            assert!(matches!(w.from_leader.recv().unwrap(), Message::Shutdown));
        }
    }
}
