//! Communication substrate: the wire format (the value/index stage
//! internals of [`crate::compress::GradientCompressor`]) + transports with
//! exact byte accounting (compression ratios in the experiment tables are
//! *measured* from these counters, never assumed).

pub mod codec;
pub mod tcp;
pub mod topology;
pub mod transport;

pub use codec::{
    decode, decode_expecting, encode, encode_segmented, is_segmented, CodecConfig, IndexFormat,
    SegEntry, ValueFormat,
};
pub use topology::{node_label, NodeRef, Topology, TreePlan};
pub use transport::{star, tree, LeaderEndpoints, Message, RelayEndpoints, WorkerEndpoints};
