//! Communication substrate: transports with exact byte accounting
//! (compression ratios in the experiment tables are *measured* from these
//! counters, never assumed). Payloads are opaque byte frames produced by
//! [`crate::compress::codec`] — this layer carries and counts them, it
//! never parses them (layering: `comms` sits above `compress` and below
//! `coordinator`; see DESIGN.md §10).

pub mod evented;
pub mod poll;
pub mod tcp;
pub mod topology;
pub mod transport;

pub use topology::{node_label, NodeRef, Topology, TreePlan};
pub use transport::{star, tree, LeaderEndpoints, Message, RelayEndpoints, WorkerEndpoints};
