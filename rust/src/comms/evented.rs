//! Evented TCP transport: ONE reactor thread drives every socket.
//!
//! The legacy bridge in [`super::tcp`] spawns four forwarding threads per
//! link — fine for n≈32, fatal for the thousands of links the federation
//! pool and deep trees are built to drive. Here all links are nonblocking
//! and multiplexed over a hand-rolled `poll(2)` loop ([`super::poll`]):
//!
//! * **Outbound**: each link owns a bounded frame queue
//!   ([`MAX_QUEUED_BYTES`]) with a partial-write cursor, so a slow peer
//!   exerts backpressure on its senders (their `deliver` blocks on a
//!   condvar) without stalling any other link. The encode-once
//!   `Arc<[u8]>` broadcast frame is queued as a 13-byte header plus the
//!   shared body — one buffer, N cursors, zero per-link copies.
//! * **Inbound**: bytes accumulate in a per-link reassembly buffer;
//!   [`super::tcp::scan_frame_len`] finds frame boundaries incrementally
//!   (validating lengths BEFORE trusting them) and complete frames are
//!   decoded with the same `read_message` the blocking path uses, then
//!   forwarded into the ordinary mpsc inboxes — so `LeaderEndpoints` /
//!   `WorkerEndpoints` consumers (RoundEngine, relays, gather policies,
//!   federation pool) are untouched.
//! * **Supervision**: a parent-side link that hits EOF or a decode error
//!   the parent did not cause (by sending `Shutdown`) injects
//!   `Message::WorkerFailed { worker }` into the parent inbox, the same
//!   fail-fast protocol as the legacy bridge — a dying link aborts the
//!   round naming the hop instead of wedging a full-sync gather.
//!
//! Byte accounting is recorded sender-side in [`CountedSender`] before a
//! frame ever reaches a queue, so counters are bit-identical across the
//! in-process, legacy-TCP and evented transports by construction (the
//! equivalence suite asserts it).

use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown as SockShutdown, TcpStream};
use std::os::fd::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

use super::poll::{poll_fds, PollFd, POLLERR, POLLHUP, POLLIN, POLLOUT};
use super::tcp::{self, socket_pairs, ChildSide};
use super::topology::{node_label, NodeRef, TreePlan};
use super::transport::{
    CountedSender, LeaderEndpoints, LinkStats, Message, MessageSink, RelayEndpoints, SinkError,
    WorkerEndpoints,
};

/// Per-link outbound queue bound. A sender whose link has this much
/// unflushed data blocks in `deliver` until the reactor drains some of it
/// — backpressure per link, never per cluster.
const MAX_QUEUED_BYTES: usize = 64 << 20;

/// Bytes read per `read(2)` into the reassembly buffer.
const READ_CHUNK: usize = 16 << 10;

/// One queued outbound frame.
enum Frame {
    /// A frame owned by this link (unicasts).
    Owned(Vec<u8>),
    /// The encode-once broadcast frame: per-link header, shared body.
    /// Every link queues the SAME `Arc` body and advances its own cursor
    /// over it — the zero-copy scatter write.
    Shared { header: [u8; 13], body: Arc<[u8]> },
}

impl Frame {
    fn total_len(&self) -> usize {
        match self {
            Frame::Owned(b) => b.len(),
            Frame::Shared { header, body } => header.len() + body.len(),
        }
    }

    /// The unwritten tail at `cursor` (header first, then shared body).
    fn chunk(&self, cursor: usize) -> &[u8] {
        match self {
            Frame::Owned(b) => &b[cursor..],
            Frame::Shared { header, body } => {
                if cursor < header.len() {
                    &header[cursor..]
                } else {
                    &body[cursor - header.len()..]
                }
            }
        }
    }
}

/// Outbound state for one link, shared between its senders and the
/// reactor. `(Frame, usize)` pairs are frames with partial-write cursors.
#[derive(Default)]
struct OutQueue {
    frames: VecDeque<(Frame, usize)>,
    queued_bytes: usize,
    /// Every sender clone has been dropped; flush then close.
    senders_gone: bool,
    /// `Shutdown` was queued: it is the last frame this link will carry.
    shutdown_queued: bool,
    /// No more frames will ever be written (peer gone or flushed-and-
    /// closed); senders get `Disconnected`.
    dead: bool,
}

#[derive(Default)]
struct LinkOut {
    q: Mutex<OutQueue>,
    /// Signalled whenever the reactor pops a frame, kills the queue, or
    /// exits — everything a blocked `deliver` waits on.
    drained: Condvar,
}

fn lock_q(out: &LinkOut) -> MutexGuard<'_, OutQueue> {
    match out.q.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Wake-pipe handle: senders nudge the reactor out of `poll` after
/// touching a queue. Nonblocking; a full pipe means a wake is already
/// pending and any other error means the reactor is gone — both ignorable.
#[derive(Clone)]
struct Wake {
    tx: Arc<UnixStream>,
}

impl Wake {
    fn signal(&self) {
        let _ = (&*self.tx).write(&[1u8]);
    }
}

/// The sender half the rest of the system sees: a [`MessageSink`] that
/// encodes at enqueue time and parks on the link's condvar when the queue
/// is over budget.
struct LinkSink {
    out: Arc<LinkOut>,
    wake: Wake,
}

impl MessageSink for LinkSink {
    fn deliver(&self, msg: Message) -> Result<(), SinkError> {
        let frame = match &msg {
            Message::ParamsDelta { round, payload } => {
                let header = tcp::encode_delta_header(*round, payload.len())
                    .map_err(|e| SinkError::Rejected(format!("{e:#}")))?;
                Frame::Shared { header, body: payload.clone() }
            }
            _ => Frame::Owned(
                tcp::encode_frame(&msg).map_err(|e| SinkError::Rejected(format!("{e:#}")))?,
            ),
        };
        let shutdown = matches!(msg, Message::Shutdown);
        let mut q = lock_q(&self.out);
        // Backpressure: an over-budget queue parks the sender until the
        // reactor drains it. An EMPTY queue always accepts, so a single
        // frame larger than the budget still goes through.
        while !q.dead
            && !q.frames.is_empty()
            && q.queued_bytes.saturating_add(frame.total_len()) > MAX_QUEUED_BYTES
        {
            q = match self.out.drained.wait(q) {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
        }
        if q.dead || q.shutdown_queued {
            // Mirrors the legacy bridge: once Shutdown is on the wire (or
            // the peer is gone) further sends fail as hung up.
            return Err(SinkError::Disconnected);
        }
        q.queued_bytes = q.queued_bytes.saturating_add(frame.total_len());
        q.frames.push_back((frame, 0));
        if shutdown {
            q.shutdown_queued = true;
        }
        drop(q);
        self.wake.signal();
        Ok(())
    }
}

impl Drop for LinkSink {
    fn drop(&mut self) {
        lock_q(&self.out).senders_gone = true;
        self.wake.signal();
    }
}

/// Everything the reactor owns for one socket (one direction-pair).
struct LinkIo {
    sock: TcpStream,
    /// Inbound reassembly buffer (bytes of zero or more partial frames).
    rd_buf: Vec<u8>,
    /// Where decoded inbound frames go; dropped when reading finishes so
    /// receivers observe disconnect exactly like the legacy bridge.
    inbox: Option<Sender<Message>>,
    /// `Some(child_id)` on a PARENT-side link: abnormal stream death
    /// injects `WorkerFailed { worker: child_id }` into the inbox.
    supervise: Option<usize>,
    read_done: bool,
    write_closed: bool,
    out: Arc<LinkOut>,
}

impl LinkIo {
    /// Decode every complete frame in the reassembly buffer. Returns
    /// `false` when reading should stop (Shutdown forwarded, receiver
    /// gone, or corrupt stream — `finish_read` already ran).
    fn pump_frames(&mut self) -> bool {
        loop {
            let total = match tcp::scan_frame_len(&self.rd_buf) {
                Ok(Some(t)) => t,
                Ok(None) => return true,
                // corrupt tag or hostile length: fail the link now
                Err(_) => {
                    self.finish_read(true);
                    return false;
                }
            };
            if self.rd_buf.len() < total {
                return true;
            }
            let msg = match tcp::read_message(&mut &self.rd_buf[..total]) {
                Ok(m) => m,
                Err(_) => {
                    self.finish_read(true);
                    return false;
                }
            };
            self.rd_buf.drain(..total);
            let shutdown = matches!(msg, Message::Shutdown);
            let delivered = self.inbox.as_ref().is_some_and(|tx| tx.send(msg).is_ok());
            if shutdown || !delivered {
                // Shutdown is the last downward frame (mirror the legacy
                // child reader); a dropped receiver means nobody is
                // listening on this side — both are clean stops.
                self.finish_read(false);
                return false;
            }
        }
    }

    /// Stop reading this link. An `abnormal` end (EOF or decode error we
    /// did not cause by queueing `Shutdown` ourselves) on a supervised
    /// parent-side link injects `WorkerFailed` first — the fail-fast link
    /// supervision protocol that turns a silent link death into an
    /// aborted round naming the hop.
    fn finish_read(&mut self, abnormal: bool) {
        if !self.read_done {
            self.read_done = true;
            if abnormal && !lock_q(&self.out).shutdown_queued {
                if let (Some(child), Some(tx)) = (self.supervise, self.inbox.as_ref()) {
                    let _ = tx.send(Message::WorkerFailed { worker: child });
                }
            }
        }
        self.inbox = None;
        self.rd_buf = Vec::new();
    }
}

enum WriteStep {
    Progress(Option<usize>),
    Block,
    Dead,
}

/// Flush as much of a link's queue as the socket accepts right now.
fn service_out(link: &mut LinkIo) {
    if link.write_closed {
        return;
    }
    let mut q = lock_q(&link.out);
    loop {
        let step = {
            let Some((frame, cursor)) = q.frames.front_mut() else { break };
            match link.sock.write(frame.chunk(*cursor)) {
                Ok(0) => WriteStep::Dead,
                Ok(n) => {
                    *cursor += n;
                    if *cursor >= frame.total_len() {
                        WriteStep::Progress(Some(frame.total_len()))
                    } else {
                        WriteStep::Progress(None)
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => WriteStep::Block,
                Err(e) if e.kind() == ErrorKind::Interrupted => WriteStep::Progress(None),
                Err(_) => WriteStep::Dead,
            }
        };
        match step {
            WriteStep::Progress(Some(done)) => {
                q.frames.pop_front();
                q.queued_bytes = q.queued_bytes.saturating_sub(done);
                link.out.drained.notify_all();
            }
            WriteStep::Progress(None) => {}
            WriteStep::Block => break,
            WriteStep::Dead => {
                q.dead = true;
                q.frames.clear();
                q.queued_bytes = 0;
                link.write_closed = true;
                link.out.drained.notify_all();
                return;
            }
        }
    }
    if q.frames.is_empty() && (q.shutdown_queued || q.senders_gone) {
        // Everything flushed and nothing more can be queued (Shutdown is
        // terminal; dropped senders cannot enqueue): send FIN so the
        // peer's reader sees a clean EOF, and fail any straggling sender
        // clones, like the legacy writer thread exiting after Shutdown.
        let _ = link.sock.shutdown(SockShutdown::Write);
        link.write_closed = true;
        q.dead = true;
        link.out.drained.notify_all();
    }
}

/// Drain inbound bytes while the socket has them, decoding frames as the
/// reassembly buffer completes them.
fn service_in(link: &mut LinkIo) {
    if link.read_done {
        return;
    }
    let mut chunk = [0u8; READ_CHUNK];
    loop {
        match link.sock.read(&mut chunk) {
            Ok(0) => {
                link.finish_read(true);
                return;
            }
            Ok(n) => {
                link.rd_buf.extend_from_slice(&chunk[..n]);
                if !link.pump_frames() {
                    return;
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => {
                link.finish_read(true);
                return;
            }
        }
    }
}

/// On reactor exit (normal or panic) every queue is killed so no sender
/// parks forever on a condvar nobody will signal.
struct AllLinksGuard(Vec<Arc<LinkOut>>);

impl Drop for AllLinksGuard {
    fn drop(&mut self) {
        for out in &self.0 {
            let mut q = lock_q(out);
            q.dead = true;
            q.frames.clear();
            q.queued_bytes = 0;
            drop(q);
            out.drained.notify_all();
        }
    }
}

fn run_reactor(mut links: Vec<LinkIo>, mut wake_rx: UnixStream) {
    let _guard = AllLinksGuard(links.iter().map(|l| l.out.clone()).collect());
    let mut wake_open = true;
    let mut fds: Vec<PollFd> = Vec::new();
    let mut idx: Vec<usize> = Vec::new();
    loop {
        for link in links.iter_mut() {
            service_out(link);
        }
        links.retain(|l| !(l.read_done && l.write_closed));
        if links.is_empty() {
            return;
        }
        fds.clear();
        idx.clear();
        if wake_open {
            fds.push(PollFd { fd: wake_rx.as_raw_fd(), events: POLLIN, revents: 0 });
        }
        for (i, link) in links.iter().enumerate() {
            let mut events = 0i16;
            if !link.read_done {
                events |= POLLIN;
            }
            if !link.write_closed && !lock_q(&link.out).frames.is_empty() {
                events |= POLLOUT;
            }
            // A fully idle link (read finished, nothing queued) is NOT
            // polled: the kernel would report its POLLHUP forever and spin
            // the loop. Its next state change arrives via the wake pipe.
            if events != 0 {
                fds.push(PollFd { fd: link.sock.as_raw_fd(), events, revents: 0 });
                idx.push(i);
            }
        }
        if fds.is_empty() {
            // Wake pipe closed (every sender everywhere is gone) and no
            // socket has work: nothing can ever change — exit, letting the
            // guard mark the queues dead.
            return;
        }
        if poll_fds(&mut fds, -1).is_err() {
            return;
        }
        let base = if wake_open {
            if fds[0].revents != 0 {
                let mut scratch = [0u8; 64];
                loop {
                    match wake_rx.read(&mut scratch) {
                        Ok(0) => {
                            wake_open = false;
                            break;
                        }
                        Ok(_) => {}
                        Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                        Err(e) if e.kind() == ErrorKind::Interrupted => {}
                        Err(_) => {
                            wake_open = false;
                            break;
                        }
                    }
                }
            }
            1
        } else {
            0
        };
        for (k, &li) in idx.iter().enumerate() {
            if fds[base + k].revents & (POLLIN | POLLERR | POLLHUP) != 0 {
                service_in(&mut links[li]);
            }
        }
    }
}

/// Accumulates links while a topology is wired, then spawns the single
/// reactor thread that owns them all.
pub struct ReactorBuilder {
    links: Vec<LinkIo>,
    wake: Wake,
    wake_rx: UnixStream,
}

impl ReactorBuilder {
    pub fn new() -> anyhow::Result<Self> {
        let (wake_tx, wake_rx) = UnixStream::pair()?;
        wake_tx.set_nonblocking(true)?;
        wake_rx.set_nonblocking(true)?;
        Ok(ReactorBuilder { links: Vec::new(), wake: Wake { tx: Arc::new(wake_tx) }, wake_rx })
    }

    /// Register the parent's half of one edge: a supervised reader into
    /// `inbox` plus a queued writer, surfaced as the parent's counted
    /// sender toward the child.
    fn add_parent_side(
        &mut self,
        sock: TcpStream,
        inbox: Sender<Message>,
        child_id: usize,
        n_workers: usize,
        down: Arc<LinkStats>,
    ) -> anyhow::Result<CountedSender> {
        sock.set_nonblocking(true)?;
        let out = Arc::new(LinkOut::default());
        self.links.push(LinkIo {
            sock,
            rd_buf: Vec::new(),
            inbox: Some(inbox),
            supervise: Some(child_id),
            read_done: false,
            write_closed: false,
            out: out.clone(),
        });
        let sink = LinkSink { out, wake: self.wake.clone() };
        Ok(CountedSender::from_sink(Arc::new(sink), down, &node_label(child_id, n_workers)))
    }

    /// Register the child's half of one edge and return its endpoints.
    fn add_child_side(
        &mut self,
        sock: TcpStream,
        child_id: usize,
        parent_label: &str,
        up: Arc<LinkStats>,
    ) -> anyhow::Result<WorkerEndpoints> {
        sock.set_nonblocking(true)?;
        let (wk_tx, wk_rx) = channel::<Message>();
        let out = Arc::new(LinkOut::default());
        self.links.push(LinkIo {
            sock,
            rd_buf: Vec::new(),
            inbox: Some(wk_tx),
            supervise: None,
            read_done: false,
            write_closed: false,
            out: out.clone(),
        });
        let sink = LinkSink { out, wake: self.wake.clone() };
        Ok(WorkerEndpoints {
            id: child_id,
            from_leader: wk_rx,
            to_leader: CountedSender::from_sink(Arc::new(sink), up, parent_label),
        })
    }

    /// Hand every registered link to the one detached reactor thread.
    pub fn spawn(self) {
        let ReactorBuilder { links, wake, wake_rx } = self;
        // The builder's wake handle must die here: the reactor learns
        // "all senders gone" from the pipe's EOF, and that must track the
        // sinks alone.
        drop(wake);
        std::thread::spawn(move || run_reactor(links, wake_rx));
    }
}

/// Wire one parent over already-paired sockets for its children
/// (evented mirror of `tcp::tcp_node`, same tap semantics).
fn evented_node(
    rb: &mut ReactorBuilder,
    parent_label: &str,
    children: Vec<(usize, (TcpStream, TcpStream))>,
    n_workers: usize,
    taps: &[usize],
) -> anyhow::Result<(LeaderEndpoints, Vec<ChildSide>)> {
    let (up_tx, up_rx) = channel::<Message>();
    let mut to_workers = Vec::with_capacity(children.len());
    let mut child_sides = Vec::with_capacity(children.len());
    let mut down_stats = Vec::with_capacity(children.len());
    let mut up_stats = Vec::with_capacity(children.len());
    let mut child_ids = Vec::with_capacity(children.len());
    for (id, (parent_sock, child_sock)) in children {
        let down = Arc::new(LinkStats::default());
        let up = Arc::new(LinkStats::default());
        let tx = rb.add_parent_side(parent_sock, up_tx.clone(), id, n_workers, down.clone())?;
        let side = if taps.contains(&id) {
            ChildSide::Raw(child_sock)
        } else {
            ChildSide::Bridged(rb.add_child_side(child_sock, id, parent_label, up.clone())?)
        };
        to_workers.push(tx);
        down_stats.push(down);
        up_stats.push(up);
        child_sides.push(side);
        child_ids.push(id);
    }
    Ok((
        LeaderEndpoints {
            to_workers,
            from_workers: up_rx,
            child_ids,
            down_stats,
            up_stats,
            bcast_stats: Arc::new(LinkStats::default()),
        },
        child_sides,
    ))
}

/// Build a star topology over loopback TCP driven by one reactor thread.
/// Drop-in replacement for [`super::transport::star`] / `tcp::tcp_star`.
pub fn evented_star(n: usize) -> anyhow::Result<(LeaderEndpoints, Vec<WorkerEndpoints>)> {
    let (leader, sides) = evented_star_tapped(n, &[])?;
    let workers = sides
        .into_iter()
        .map(|s| match s {
            ChildSide::Bridged(w) => w,
            ChildSide::Raw(_) => unreachable!("untapped builders bridge every child"),
        })
        .collect();
    Ok((leader, workers))
}

/// [`evented_star`] with designated worker slots left as raw (blocking)
/// sockets for fault-injection tests.
pub fn evented_star_tapped(
    n: usize,
    taps: &[usize],
) -> anyhow::Result<(LeaderEndpoints, Vec<ChildSide>)> {
    let mut rb = ReactorBuilder::new()?;
    let pairs = socket_pairs(n)?;
    let out = evented_node(&mut rb, "root", (0..n).zip(pairs).collect(), n, taps)?;
    rb.spawn();
    Ok(out)
}

/// Build a tree topology over loopback TCP with EVERY edge (root↔relay,
/// relay↔worker) multiplexed onto the same single reactor thread. Mirrors
/// `tcp::tcp_tree`'s slot placement exactly — the equivalence tests pin
/// the two against each other.
pub fn evented_tree(
    plan: &TreePlan,
) -> anyhow::Result<(LeaderEndpoints, Vec<RelayEndpoints>, Vec<WorkerEndpoints>)> {
    let (leader, relays, workers, raw) = evented_tree_tapped(plan, &[])?;
    debug_assert!(raw.is_empty());
    let workers = workers
        .into_iter()
        .map(|w| w.expect("every worker has a parent link"))
        .collect();
    Ok((leader, relays, workers))
}

/// [`evented_tree`] with designated WORKER leaves left as raw sockets
/// (same contract as `tcp::tcp_tree_tapped`).
#[allow(clippy::type_complexity)]
pub fn evented_tree_tapped(
    plan: &TreePlan,
    taps: &[usize],
) -> anyhow::Result<(
    LeaderEndpoints,
    Vec<RelayEndpoints>,
    Vec<Option<WorkerEndpoints>>,
    Vec<(usize, TcpStream)>,
)> {
    let n = plan.n_workers;
    let total = n + plan.relays.len();
    let mut rb = ReactorBuilder::new()?;
    let mut pairs: Vec<Option<(TcpStream, TcpStream)>> =
        socket_pairs(total)?.into_iter().map(Some).collect();
    let mut take = |ids: &[usize]| -> Vec<(usize, (TcpStream, TcpStream))> {
        ids.iter()
            .map(|&id| (id, pairs[id].take().expect("each node has exactly one parent")))
            .collect()
    };

    let mut worker_slots: Vec<Option<WorkerEndpoints>> = (0..n).map(|_| None).collect();
    let mut up_slots: Vec<Option<WorkerEndpoints>> =
        (0..plan.relays.len()).map(|_| None).collect();
    let mut down_slots: Vec<Option<LeaderEndpoints>> =
        (0..plan.relays.len()).map(|_| None).collect();
    let mut raw: Vec<(usize, TcpStream)> = Vec::new();

    let mut place = |children: &[NodeRef],
                     sides: Vec<ChildSide>,
                     worker_slots: &mut Vec<Option<WorkerEndpoints>>,
                     up_slots: &mut Vec<Option<WorkerEndpoints>>| {
        for (&child, side) in children.iter().zip(sides) {
            match (child, side) {
                (NodeRef::Worker(w), ChildSide::Bridged(s)) => worker_slots[w] = Some(s),
                (NodeRef::Worker(w), ChildSide::Raw(sock)) => raw.push((w, sock)),
                (NodeRef::Relay(r), ChildSide::Bridged(s)) => up_slots[r] = Some(s),
                (NodeRef::Relay(_), ChildSide::Raw(_)) => {
                    unreachable!("taps name leaf workers, never relays")
                }
            }
        }
    };

    let root_ids: Vec<usize> = plan.root_children.iter().map(|&c| plan.node_id(c)).collect();
    let (leader, sides) = evented_node(&mut rb, "root", take(&root_ids), n, taps)?;
    place(&plan.root_children, sides, &mut worker_slots, &mut up_slots);
    for (r, spec) in plan.relays.iter().enumerate() {
        let ids: Vec<usize> = spec.children.iter().map(|&c| plan.node_id(c)).collect();
        let (down, sides) = evented_node(&mut rb, &node_label(n + r, n), take(&ids), n, taps)?;
        down_slots[r] = Some(down);
        place(&spec.children, sides, &mut worker_slots, &mut up_slots);
    }
    rb.spawn();

    let relays: Vec<RelayEndpoints> = plan
        .relays
        .iter()
        .enumerate()
        .map(|(r, spec)| RelayEndpoints {
            id: n + r,
            level: spec.level,
            n_leaves: spec.leaves.len(),
            child_leaves: spec.children.iter().map(|&c| plan.leaves_of(c)).collect(),
            up: up_slots[r].take().expect("every relay has a parent link"),
            down: down_slots[r].take().expect("every relay has child links"),
        })
        .collect();
    Ok((leader, relays, worker_slots, raw))
}

#[cfg(test)]
mod tests {
    use super::super::topology::Topology;
    use super::*;
    use std::time::Duration;

    const WAIT: Duration = Duration::from_secs(30);

    fn os_thread_count() -> usize {
        let status = std::fs::read_to_string("/proc/self/status").unwrap();
        status
            .lines()
            .find_map(|l| l.strip_prefix("Threads:"))
            .unwrap()
            .trim()
            .parse()
            .unwrap()
    }

    #[test]
    fn evented_star_roundtrip() {
        let (leader, workers) = evented_star(2).unwrap();
        for round in 0..3u64 {
            for tx in &leader.to_workers {
                tx.send(Message::Params { round, data: vec![round as f32; 4] }).unwrap();
            }
            for w in &workers {
                match w.from_leader.recv_timeout(WAIT).unwrap() {
                    Message::Params { round: r, data } => {
                        assert_eq!(r, round);
                        assert_eq!(data, vec![round as f32; 4]);
                    }
                    other => panic!("unexpected {other:?}"),
                }
                w.to_leader
                    .send(Message::SparseUpdate {
                        round,
                        worker: w.id,
                        payload: vec![w.id as u8; 3],
                        loss: 0.0,
                        examples: 1,
                        mem_norm: 0.0,
                        participants: 1,
                    })
                    .unwrap();
            }
            let mut seen = [false; 2];
            for _ in 0..2 {
                match leader.recv_timeout(WAIT).unwrap() {
                    Some(Message::SparseUpdate { round: r, worker, payload, .. }) => {
                        assert_eq!(r, round);
                        assert_eq!(payload, vec![worker as u8; 3]);
                        assert!(!seen[worker]);
                        seen[worker] = true;
                    }
                    other => panic!("unexpected {other:?}"),
                }
            }
        }
        for tx in &leader.to_workers {
            tx.send(Message::Shutdown).unwrap();
        }
        for w in &workers {
            assert!(matches!(w.from_leader.recv_timeout(WAIT).unwrap(), Message::Shutdown));
        }
        // post-Shutdown sends fail like the legacy bridge
        assert!(leader.to_workers[0].send(Message::Shutdown).is_err());
        assert!(leader.down_stats[0].snapshot().1 > 0);
        assert!(leader.up_stats[0].snapshot().1 > 0);
    }

    #[test]
    fn evented_tree_carries_every_hop() {
        // Mirror of tcp.rs's tcp_tree_carries_every_hop: same frames, and
        // the per-hop byte counters must be IDENTICAL (accounting is
        // sender-side, transport-independent).
        let plan = Topology::Tree { fanout: 2, depth: Some(2) }.plan(4).unwrap();
        let (leader, relays, workers) = evented_tree(&plan).unwrap();
        assert_eq!(leader.child_ids, vec![4, 5]);
        assert_eq!(relays.len(), 2);

        leader.to_workers[0]
            .send(Message::Params { round: 1, data: vec![2.0; 4] })
            .unwrap();
        let got = relays[0].up.from_leader.recv_timeout(WAIT).unwrap();
        assert!(matches!(&got, Message::Params { round: 1, .. }));
        relays[0].down.to_workers[0].send(got).unwrap();
        match workers[0].from_leader.recv_timeout(WAIT).unwrap() {
            Message::Params { round: 1, data } => assert_eq!(data, vec![2.0; 4]),
            other => panic!("unexpected {other:?}"),
        }
        workers[0]
            .to_leader
            .send(Message::SparseUpdate {
                round: 1,
                worker: 0,
                payload: vec![7u8; 5],
                loss: 0.0,
                examples: 1,
                mem_norm: 0.0,
                participants: 1,
            })
            .unwrap();
        match relays[0].down.recv_timeout(WAIT).unwrap() {
            Some(Message::SparseUpdate { worker: 0, participants: 1, .. }) => {}
            other => panic!("unexpected {other:?}"),
        }
        relays[0]
            .up
            .to_leader
            .send(Message::SparseUpdate {
                round: 1,
                worker: 4,
                payload: vec![7u8; 8],
                loss: 0.0,
                examples: 2,
                mem_norm: 0.0,
                participants: 2,
            })
            .unwrap();
        match leader.recv_timeout(WAIT).unwrap() {
            Some(Message::SparseUpdate { worker: 4, participants: 2, .. }) => {}
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(leader.down_stats[0].snapshot(), (1, 16));
        assert_eq!(relays[0].down.down_stats[0].snapshot(), (1, 16));
        assert_eq!(relays[0].down.up_stats[0].snapshot(), (1, 5));
        assert_eq!(leader.up_stats[0].snapshot(), (1, 8));

        for tx in &leader.to_workers {
            tx.send(Message::Shutdown).unwrap();
        }
        for r in &relays {
            assert!(matches!(
                r.up.from_leader.recv_timeout(WAIT).unwrap(),
                Message::Shutdown
            ));
            for tx in &r.down.to_workers {
                tx.send(Message::Shutdown).unwrap();
            }
        }
        for w in &workers {
            assert!(matches!(w.from_leader.recv_timeout(WAIT).unwrap(), Message::Shutdown));
        }
    }

    #[test]
    fn star_256_runs_on_one_reactor_thread() {
        let before = os_thread_count();
        let (leader, workers) = evented_star(256).unwrap();
        let after = os_thread_count();
        // ONE reactor thread drives all 512 socket ends; the legacy
        // bridge would have spawned 4 × 256 = 1024 forwarding threads.
        // The allowance keeps the assert robust against sibling tests
        // spawning their own (few) threads concurrently in this process
        // while still being ~30x below what thread-per-connection needs.
        assert!(
            after.saturating_sub(before) <= 32,
            "expected ~1 new thread, got {} (before={before}, after={after})",
            after.saturating_sub(before)
        );

        let payload: Arc<[u8]> = vec![7u8; 1024].into();
        leader.broadcast_shared(1, payload.clone()).unwrap();
        for w in &workers {
            match w.from_leader.recv_timeout(WAIT).unwrap() {
                Message::ParamsDelta { round: 1, payload: p } => {
                    assert_eq!(&p[..], &payload[..])
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        for w in &workers {
            w.to_leader
                .send(Message::SparseUpdate {
                    round: 1,
                    worker: w.id,
                    payload: vec![1u8; 8],
                    loss: 0.0,
                    examples: 1,
                    mem_norm: 0.0,
                    participants: 1,
                })
                .unwrap();
        }
        let mut seen = vec![false; 256];
        for _ in 0..256 {
            match leader.recv_timeout(WAIT).unwrap() {
                Some(Message::SparseUpdate { worker, .. }) => {
                    assert!(!seen[worker]);
                    seen[worker] = true;
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        // broadcast counted once, not 256 times
        assert_eq!(leader.bcast_stats.snapshot(), (1, 1024));
        for tx in &leader.to_workers {
            tx.send(Message::Shutdown).unwrap();
        }
        for w in &workers {
            assert!(matches!(w.from_leader.recv_timeout(WAIT).unwrap(), Message::Shutdown));
        }
    }

    #[test]
    fn large_frames_resume_across_partial_writes() {
        // 1 MiB frames vastly exceed a loopback socket buffer, so the
        // reactor must park mid-frame on WouldBlock and resume the cursor
        // — and the total (128 MiB) exceeds MAX_QUEUED_BYTES, so sender
        // backpressure engages while the reader drains concurrently.
        let (leader, workers) = evented_star(1).unwrap();
        let body: Arc<[u8]> = vec![0xABu8; 1 << 20].into();
        let n_frames = 128u64;
        let sender = {
            let leader_tx = leader.to_workers[0].clone();
            let body = body.clone();
            std::thread::spawn(move || {
                for round in 0..n_frames {
                    leader_tx
                        .send_uncounted(Message::ParamsDelta { round, payload: body.clone() })
                        .unwrap();
                }
            })
        };
        let w = &workers[0];
        for round in 0..n_frames {
            match w.from_leader.recv_timeout(WAIT).unwrap() {
                Message::ParamsDelta { round: r, payload } => {
                    assert_eq!(r, round, "frames must arrive in order");
                    assert_eq!(&payload[..], &body[..]);
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        sender.join().unwrap();
        for tx in &leader.to_workers {
            tx.send(Message::Shutdown).unwrap();
        }
        assert!(matches!(w.from_leader.recv_timeout(WAIT).unwrap(), Message::Shutdown));
    }

    #[test]
    fn dead_child_socket_injects_worker_failed() {
        // Evented mirror of the legacy-bridge supervision regression: a
        // corrupt tag mid-stream must surface as WorkerFailed naming the
        // hop, not a silent reader death.
        let (leader, sides) = evented_star_tapped(2, &[1]).unwrap();
        let mut healthy = None;
        let mut raw = None;
        for side in sides {
            match side {
                ChildSide::Bridged(w) => healthy = Some(w),
                ChildSide::Raw(s) => raw = Some(s),
            }
        }
        let healthy = healthy.unwrap();
        let mut raw = raw.unwrap();
        raw.write_all(&[0xFF; 16]).unwrap();
        match leader.recv_timeout(WAIT).unwrap() {
            Some(Message::WorkerFailed { worker: 1 }) => {}
            other => panic!("expected WorkerFailed for worker 1, got {other:?}"),
        }
        healthy.to_leader.send(Message::ResyncRequest { worker: 0 }).unwrap();
        match leader.recv_timeout(WAIT).unwrap() {
            Some(Message::ResyncRequest { worker: 0 }) => {}
            other => panic!("unexpected {other:?}"),
        }
        for tx in &leader.to_workers {
            let _ = tx.send(Message::Shutdown);
        }
    }

    #[test]
    fn clean_shutdown_is_not_reported_as_failure() {
        let (leader, workers) = evented_star(1).unwrap();
        leader.to_workers[0].send(Message::Shutdown).unwrap();
        let w = workers.into_iter().next().unwrap();
        assert!(matches!(w.from_leader.recv_timeout(WAIT).unwrap(), Message::Shutdown));
        drop(w); // closes the child's sink — reactor flushes + FINs the socket
        match leader.recv_timeout(Duration::from_millis(500)) {
            Ok(Some(msg)) => panic!("clean shutdown surfaced {msg:?}"),
            Ok(None) | Err(_) => {}
        }
    }

    #[test]
    fn oversized_encode_is_rejected_with_cause() {
        // The evented sink validates at enqueue time; the error must be
        // the encoder's rejection, not a generic hang-up.
        let (leader, workers) = evented_star(1).unwrap();
        let err = leader.to_workers[0]
            .send(Message::ResyncRequest { worker: 1usize << 40 })
            .expect_err("oversized id must be rejected");
        let msg = format!("{err:#}");
        assert!(msg.contains("rejected"), "{msg}");
        for tx in &leader.to_workers {
            tx.send(Message::Shutdown).unwrap();
        }
        assert!(matches!(
            workers[0].from_leader.recv_timeout(WAIT).unwrap(),
            Message::Shutdown
        ));
    }
}
