//! Simulated cluster transport with exact byte accounting.
//!
//! The paper's experiments measure accuracy at a fixed *communication
//! budget*, not wall-clock network time, so the default transport is
//! in-process: one channel pair per link plus a broadcast path, with
//! every payload's byte length recorded on per-link counters. The TCP
//! transport in [`super::tcp`] implements the same trait for multi-process
//! runs; integration tests assert the two produce identical traffic.
//!
//! Links form either a star (every worker to the root) or a
//! [`super::topology::Topology`] tree, where intermediate *relays* gather
//! their children, merge in the sparse domain, and forward one frame
//! upward ([`crate::coordinator::relay`]). Either way each parent holds a
//! [`LeaderEndpoints`] over its direct children and each child holds a
//! [`WorkerEndpoints`] toward its parent, so the gather/broadcast machinery
//! is identical at every level of the tree.
//!
//! Accounting convention: per-child unicasts (dense params, resyncs,
//! updates) count once per link; the encode-once broadcast frame
//! ([`Message::ParamsDelta`], shared via `Arc`) counts ONCE on
//! [`LeaderEndpoints::bcast_stats`] *per broadcasting node* regardless of
//! its child count — it models a broadcast/multicast domain carrying one
//! frame per hop, and both transports apply the same convention so their
//! measured bytes agree.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Duration;

use super::topology::{node_label, NodeRef, TreePlan};

/// Messages exchanged between parents and children each round.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Parent -> children: full model broadcast (round t's omega). The
    /// dense fallback of the delta downlink: round 0, periodic resyncs,
    /// and on-demand [`Message::ResyncRequest`] replies.
    Params { round: u64, data: Vec<f32> },
    /// Parent -> children: encoded sparse param delta omega^t - omega^{t-1}
    /// (codec bytes). Encoded once at the root and shared down the tree —
    /// the `Arc` payload IS the encode-once broadcast frame, re-shared (not
    /// re-encoded) at every relay hop.
    ParamsDelta { round: u64, payload: Arc<[u8]> },
    /// Child -> parent: encoded sparse update (codec bytes) plus the
    /// subtree's round loss and residual-memory norm (metrics side-band).
    /// A leaf worker sends `participants = 1`; a relay sends the merged
    /// union of its subtree with `participants` = the number of leaf
    /// workers folded into the payload, so the root's averaging scale and
    /// quorum accounting stay in units of workers at any tree depth.
    SparseUpdate {
        round: u64,
        worker: usize,
        payload: Vec<u8>,
        loss: f32,
        examples: u64,
        mem_norm: f32,
        participants: u32,
    },
    /// Child -> parent: "I cannot apply a delta (no base params); unicast
    /// me a dense `Params` frame for this round." Control-plane only.
    /// Answered locally by the child's parent (the root, or a relay from
    /// its tracked shadow), never forwarded further up.
    ResyncRequest { worker: usize },
    /// Child -> parent: this node (a worker, or a whole relay subtree) hit
    /// a fatal error and is exiting. Without it a FullSync gather would
    /// block forever on a quorum that can never complete; the parent
    /// aborts the round instead, the abort propagates to the root, and the
    /// cluster surfaces the failing node's own error. Control-plane only.
    WorkerFailed { worker: usize },
    /// Parent -> children: shut down cleanly (relays forward it down).
    Shutdown,
}

impl Message {
    /// Wire size in bytes, as a real network would see it (payload only;
    /// we deliberately exclude per-message framing, matching how the paper
    /// counts "number of gradients communicated"). Control messages
    /// (shutdown, resync requests) cost nothing under that accounting.
    pub fn wire_bytes(&self) -> u64 {
        match self {
            Message::Params { data, .. } => 4 * data.len() as u64,
            Message::ParamsDelta { payload, .. } => payload.len() as u64,
            Message::SparseUpdate { payload, .. } => payload.len() as u64,
            Message::ResyncRequest { .. } => 0,
            Message::WorkerFailed { .. } => 0,
            Message::Shutdown => 0,
        }
    }
}

/// Byte counters for one direction of one link.
#[derive(Debug, Default)]
pub struct LinkStats {
    pub messages: AtomicU64,
    pub bytes: AtomicU64,
}

impl LinkStats {
    fn record(&self, bytes: u64) {
        self.messages.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> (u64, u64) {
        (self.messages.load(Ordering::Relaxed), self.bytes.load(Ordering::Relaxed))
    }
}

/// Marker every dead-link send error carries. The cluster's join loop
/// classifies node errors containing it as CASCADES (a neighbour reporting
/// the link a dying node took down) and prefers any other error as the
/// root cause — keep the error construction below and that check in sync
/// through this constant.
pub const LINK_HUNG_UP: &str = "hung up";

/// Why a [`MessageSink`] refused a message.
#[derive(Debug)]
pub enum SinkError {
    /// The far side is gone: channel receiver dropped, or the evented
    /// link is marked dead.
    Disconnected,
    /// The message can never be framed for this wire (e.g. a length that
    /// overflows the u32 prefix). Carries the encoder's own diagnosis.
    Rejected(String),
}

/// One outbound half of a link, behind [`CountedSender`]. The in-process
/// transport is a plain mpsc [`Sender`]; the legacy TCP bridge is a
/// Sender drained by a per-link writer thread; the evented transport is a
/// bounded per-link frame queue serviced by the shared reactor thread
/// (delivery there may BLOCK briefly for write backpressure).
pub trait MessageSink: Send + Sync {
    fn deliver(&self, msg: Message) -> Result<(), SinkError>;
}

impl MessageSink for Sender<Message> {
    fn deliver(&self, msg: Message) -> Result<(), SinkError> {
        self.send(msg).map_err(|_| SinkError::Disconnected)
    }
}

/// A counted sender: records bytes on the shared link stats, then sends.
/// Clones share the same sink and counters (the cluster keeps one
/// aside per node thread to report fatal errors). Each sender knows the
/// *peer node* on the far end of its link, so a multi-hop failure names
/// the hop that actually died instead of a generic "peer hung up".
#[derive(Clone)]
pub struct CountedSender {
    tx: Arc<dyn MessageSink>,
    stats: Arc<LinkStats>,
    peer: Arc<str>,
}

impl CountedSender {
    pub fn new(tx: Sender<Message>, stats: Arc<LinkStats>, peer: &str) -> Self {
        Self::from_sink(Arc::new(tx), stats, peer)
    }

    /// Wrap a non-channel sink (the evented transport's link queues).
    pub fn from_sink(tx: Arc<dyn MessageSink>, stats: Arc<LinkStats>, peer: &str) -> Self {
        CountedSender { tx, stats, peer: Arc::from(peer) }
    }

    /// The node label on the receiving end of this link (e.g. `worker-3`,
    /// `relay-1`, `root`).
    pub fn peer(&self) -> &str {
        &self.peer
    }

    pub fn send(&self, msg: Message) -> anyhow::Result<()> {
        self.stats.record(msg.wire_bytes());
        self.deliver_named(msg)
    }

    /// Deliver without touching this link's counters. Used by the
    /// encode-once broadcast path, whose single shared frame is recorded
    /// once on [`LeaderEndpoints::bcast_stats`] instead of once per link.
    pub fn send_uncounted(&self, msg: Message) -> anyhow::Result<()> {
        self.deliver_named(msg)
    }

    fn deliver_named(&self, msg: Message) -> anyhow::Result<()> {
        self.tx.deliver(msg).map_err(|e| match e {
            SinkError::Disconnected => anyhow::anyhow!("peer {} {LINK_HUNG_UP}", self.peer),
            SinkError::Rejected(why) => {
                anyhow::anyhow!("send to peer {} rejected: {why}", self.peer)
            }
        })
    }
}

/// Endpoints a parent (the root, or a relay's downward face) holds over
/// its direct children.
pub struct LeaderEndpoints {
    /// Broadcast senders, one per direct child (uplink stats shared).
    pub to_workers: Vec<CountedSender>,
    /// Single merged receiver for child updates.
    pub from_workers: Receiver<Message>,
    /// Global node id of each direct child, in slot order (workers `0..n`,
    /// relays `n..n+R`; the identity map for a star).
    pub child_ids: Vec<usize>,
    /// Downlink (parent->child) unicast traffic, per child.
    pub down_stats: Vec<Arc<LinkStats>>,
    /// Uplink (child->parent) traffic, per child. At the root these ARE
    /// the measured root-ingress counters.
    pub up_stats: Vec<Arc<LinkStats>>,
    /// Shared-frame broadcast traffic: an encode-once frame delivered to
    /// every child is recorded here exactly once (a broadcast medium /
    /// multicast egress carries it once per hop), while per-child unicasts
    /// (dense fallbacks, resyncs) stay on [`Self::down_stats`].
    pub bcast_stats: Arc<LinkStats>,
}

impl LeaderEndpoints {
    /// Block for the next child→parent message. Errors when every child
    /// sender has hung up.
    pub fn recv(&self) -> anyhow::Result<Message> {
        self.from_workers
            .recv()
            .map_err(|_| anyhow::anyhow!("child channels closed (all peers hung up)"))
    }

    /// Wait up to `timeout` for the next child→parent message; `Ok(None)`
    /// on timeout. Both transports support this: the in-process link is a
    /// channel, and the TCP bridge forwards socket reads into the same
    /// channel — so a quorum gather's drain deadline behaves identically
    /// on either wire.
    pub fn recv_timeout(&self, timeout: Duration) -> anyhow::Result<Option<Message>> {
        match self.from_workers.recv_timeout(timeout) {
            Ok(m) => Ok(Some(m)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => {
                Err(anyhow::anyhow!("child channels closed (all peers hung up)"))
            }
        }
    }

    /// Send one shared encoded frame to every child, recording its bytes
    /// once on the broadcast counter — the encode-once broadcast path.
    pub fn broadcast_shared(&self, round: u64, payload: Arc<[u8]>) -> anyhow::Result<()> {
        self.bcast_stats.record(payload.len() as u64);
        for tx in &self.to_workers {
            tx.send_uncounted(Message::ParamsDelta { round, payload: payload.clone() })?;
        }
        Ok(())
    }

    /// Total (messages, bytes) the downlink carried: per-child unicasts
    /// plus shared broadcast frames.
    pub fn downlink_total(&self) -> (u64, u64) {
        let (m, b) = total(&self.down_stats);
        let (bm, bb) = self.bcast_stats.snapshot();
        (m + bm, b + bb)
    }
}

/// Endpoints one child holds toward its parent. `id` is the node's GLOBAL
/// id: the worker id for a leaf, `n_workers + relay_index` for a relay's
/// upward face.
pub struct WorkerEndpoints {
    pub id: usize,
    pub from_leader: Receiver<Message>,
    pub to_leader: CountedSender,
}

impl WorkerEndpoints {
    /// After a FAILED upward send: was the parent legitimately shutting
    /// down? Parents always forward `Shutdown` down BEFORE dropping their
    /// links, but over the TCP bridge that frame may still be in the
    /// socket/reader pipeline — so wait a bounded moment for it instead of
    /// peeking the inbox. `true` means a `Shutdown` arrived (clean exit);
    /// `false` (disconnect or timeout) means the link really died. Shared
    /// by the worker and relay loops so the race protocol has one home.
    pub fn shutdown_pending(&self, timeout: Duration) -> bool {
        loop {
            match self.from_leader.recv_timeout(timeout) {
                Ok(Message::Shutdown) => return true,
                Ok(_) => continue,
                Err(_) => return false,
            }
        }
    }
}

/// One relay node's endpoints: a child face toward its parent and a
/// parent face over its children. Consumed by
/// [`crate::coordinator::relay::run_relay`].
pub struct RelayEndpoints {
    /// Global node id (`n_workers + relay_index`).
    pub id: usize,
    /// Tree level (1 = direct child of the root).
    pub level: usize,
    /// Leaf workers covered by this relay's subtree.
    pub n_leaves: usize,
    /// Leaf workers covered by each direct child, in slot order.
    pub child_leaves: Vec<usize>,
    /// Toward the parent.
    pub up: WorkerEndpoints,
    /// Over the children.
    pub down: LeaderEndpoints,
}

/// Wire one parent to a set of children over in-process channels. Returns
/// the parent's endpoints plus the child-side endpoint for each child, in
/// slot order.
fn channel_node(
    parent_label: &str,
    child_ids: &[usize],
    n_workers: usize,
) -> (LeaderEndpoints, Vec<WorkerEndpoints>) {
    let (up_tx, up_rx) = channel::<Message>();
    let mut to_workers = Vec::with_capacity(child_ids.len());
    let mut children = Vec::with_capacity(child_ids.len());
    let mut down_stats = Vec::with_capacity(child_ids.len());
    let mut up_stats = Vec::with_capacity(child_ids.len());
    for &id in child_ids {
        let (down_tx, down_rx) = channel::<Message>();
        let down = Arc::new(LinkStats::default());
        let up = Arc::new(LinkStats::default());
        to_workers.push(CountedSender::new(down_tx, down.clone(), &node_label(id, n_workers)));
        children.push(WorkerEndpoints {
            id,
            from_leader: down_rx,
            to_leader: CountedSender::new(up_tx.clone(), up.clone(), parent_label),
        });
        down_stats.push(down);
        up_stats.push(up);
    }
    (
        LeaderEndpoints {
            to_workers,
            from_workers: up_rx,
            child_ids: child_ids.to_vec(),
            down_stats,
            up_stats,
            bcast_stats: Arc::new(LinkStats::default()),
        },
        children,
    )
}

/// Build an in-process star topology with `n` workers.
pub fn star(n: usize) -> (LeaderEndpoints, Vec<WorkerEndpoints>) {
    let ids: Vec<usize> = (0..n).collect();
    channel_node("root", &ids, n)
}

/// Build an in-process tree from a resolved [`TreePlan`]. A plan with zero
/// relays (star, or `tree:fanout=n,depth=1`) produces exactly the wiring
/// of [`star`] — same links, same ids, same counters.
pub fn tree(plan: &TreePlan) -> (LeaderEndpoints, Vec<RelayEndpoints>, Vec<WorkerEndpoints>) {
    let n = plan.n_workers;
    let mut worker_slots: Vec<Option<WorkerEndpoints>> = (0..n).map(|_| None).collect();
    let mut up_slots: Vec<Option<WorkerEndpoints>> =
        (0..plan.relays.len()).map(|_| None).collect();
    let mut down_slots: Vec<Option<LeaderEndpoints>> =
        (0..plan.relays.len()).map(|_| None).collect();

    let place = |children: &[NodeRef],
                 sides: Vec<WorkerEndpoints>,
                 worker_slots: &mut Vec<Option<WorkerEndpoints>>,
                 up_slots: &mut Vec<Option<WorkerEndpoints>>| {
        for (&child, side) in children.iter().zip(sides) {
            match child {
                NodeRef::Worker(w) => worker_slots[w] = Some(side),
                NodeRef::Relay(r) => up_slots[r] = Some(side),
            }
        }
    };

    let root_ids: Vec<usize> = plan.root_children.iter().map(|&c| plan.node_id(c)).collect();
    let (leader, sides) = channel_node("root", &root_ids, n);
    place(&plan.root_children, sides, &mut worker_slots, &mut up_slots);

    for (r, spec) in plan.relays.iter().enumerate() {
        let ids: Vec<usize> = spec.children.iter().map(|&c| plan.node_id(c)).collect();
        let (down, sides) = channel_node(&node_label(n + r, n), &ids, n);
        down_slots[r] = Some(down);
        place(&spec.children, sides, &mut worker_slots, &mut up_slots);
    }

    let relays: Vec<RelayEndpoints> = plan
        .relays
        .iter()
        .enumerate()
        .map(|(r, spec)| RelayEndpoints {
            id: n + r,
            level: spec.level,
            n_leaves: spec.leaves.len(),
            child_leaves: spec.children.iter().map(|&c| plan.leaves_of(c)).collect(),
            up: up_slots[r].take().expect("every relay has a parent link"),
            down: down_slots[r].take().expect("every relay has child links"),
        })
        .collect();
    let workers = worker_slots
        .into_iter()
        .map(|w| w.expect("every worker has a parent link"))
        .collect();
    (leader, relays, workers)
}

/// Total (messages, bytes) across a set of link stats.
pub fn total(stats: &[Arc<LinkStats>]) -> (u64, u64) {
    stats.iter().fold((0, 0), |(m, b), s| {
        let (sm, sb) = s.snapshot();
        (m + sm, b + sb)
    })
}

#[cfg(test)]
mod tests {
    use super::super::topology::Topology;
    use super::*;

    #[test]
    fn star_delivers_both_directions() {
        let (leader, workers) = star(3);
        assert_eq!(leader.child_ids, vec![0, 1, 2]);
        for (i, tx) in leader.to_workers.iter().enumerate() {
            tx.send(Message::Params { round: 1, data: vec![i as f32; 4] }).unwrap();
        }
        let handles: Vec<_> = workers
            .into_iter()
            .map(|w| {
                std::thread::spawn(move || {
                    let msg = w.from_leader.recv().unwrap();
                    match msg {
                        Message::Params { round, data } => {
                            assert_eq!(round, 1);
                            assert_eq!(data[0], w.id as f32);
                        }
                        _ => panic!("unexpected message"),
                    }
                    w.to_leader
                        .send(Message::SparseUpdate {
                            round: 1,
                            worker: w.id,
                            payload: vec![0u8; 10 + w.id],
                            loss: 0.5,
                            examples: 8,
                            mem_norm: 0.0,
                            participants: 1,
                        })
                        .unwrap();
                })
            })
            .collect();
        let mut seen = std::collections::HashSet::new();
        for _ in 0..3 {
            match leader.from_workers.recv().unwrap() {
                Message::SparseUpdate { worker, .. } => {
                    seen.insert(worker);
                }
                _ => panic!("unexpected"),
            }
        }
        assert_eq!(seen.len(), 3);
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn byte_accounting_exact() {
        let (leader, workers) = star(2);
        leader.to_workers[0]
            .send(Message::Params { round: 0, data: vec![0.0; 100] })
            .unwrap();
        workers[0]
            .to_leader
            .send(Message::SparseUpdate {
                round: 0,
                worker: 0,
                payload: vec![0u8; 37],
                loss: 0.0,
                examples: 1,
                mem_norm: 0.0,
                participants: 1,
            })
            .unwrap();
        assert_eq!(leader.down_stats[0].snapshot(), (1, 400));
        assert_eq!(leader.up_stats[0].snapshot(), (1, 37));
        assert_eq!(leader.down_stats[1].snapshot(), (0, 0));
        let (msgs, bytes) = total(&leader.down_stats);
        assert_eq!((msgs, bytes), (1, 400));
    }

    #[test]
    fn shutdown_costs_nothing() {
        assert_eq!(Message::Shutdown.wire_bytes(), 0);
        assert_eq!(Message::ResyncRequest { worker: 3 }.wire_bytes(), 0);
        assert_eq!(Message::WorkerFailed { worker: 1 }.wire_bytes(), 0);
    }

    #[test]
    fn broadcast_shared_counts_frame_once() {
        let (leader, workers) = star(3);
        let frame: Arc<[u8]> = vec![0u8; 64].into();
        leader.broadcast_shared(5, frame).unwrap();
        // every worker receives the same frame...
        for w in &workers {
            match w.from_leader.recv().unwrap() {
                Message::ParamsDelta { round, payload } => {
                    assert_eq!(round, 5);
                    assert_eq!(payload.len(), 64);
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        // ...but the wire carried it exactly once.
        assert_eq!(leader.bcast_stats.snapshot(), (1, 64));
        assert_eq!(total(&leader.down_stats), (0, 0));
        assert_eq!(leader.downlink_total(), (1, 64));
        // a dense unicast on top still lands on the per-link counters
        leader.to_workers[1]
            .send(Message::Params { round: 5, data: vec![0.0; 10] })
            .unwrap();
        assert_eq!(leader.down_stats[1].snapshot(), (1, 40));
        assert_eq!(leader.downlink_total(), (2, 104));
    }

    #[test]
    fn recv_timeout_times_out_then_delivers() {
        let (leader, workers) = star(1);
        // empty queue: timeout yields Ok(None), not an error
        assert!(leader
            .recv_timeout(Duration::from_millis(5))
            .unwrap()
            .is_none());
        workers[0]
            .to_leader
            .send(Message::ResyncRequest { worker: 0 })
            .unwrap();
        match leader.recv_timeout(Duration::from_millis(100)).unwrap() {
            Some(Message::ResyncRequest { worker: 0 }) => {}
            other => panic!("unexpected {other:?}"),
        }
        // all senders gone: disconnected is a hard error on both recvs
        drop(workers);
        assert!(leader.recv_timeout(Duration::from_millis(1)).is_err());
        assert!(leader.recv().is_err());
    }

    #[test]
    fn send_uncounted_leaves_counters_alone() {
        let (leader, workers) = star(1);
        leader.to_workers[0]
            .send_uncounted(Message::Params { round: 0, data: vec![1.0; 8] })
            .unwrap();
        assert_eq!(leader.down_stats[0].snapshot(), (0, 0));
        assert!(matches!(
            workers[0].from_leader.recv().unwrap(),
            Message::Params { .. }
        ));
    }

    #[test]
    fn send_error_names_the_dead_peer() {
        // Attributable link errors: a hung-up link must say WHICH node
        // died, so multi-hop failures can be traced to the failing hop.
        let (leader, workers) = star(4);
        drop(workers); // every worker gone
        let err = leader.to_workers[2]
            .send(Message::Shutdown)
            .expect_err("send into a dropped receiver must fail");
        assert!(format!("{err}").contains("worker-2"), "{err}");
        assert_eq!(leader.to_workers[2].peer(), "worker-2");

        let (leader2, workers2) = star(1);
        drop(leader2);
        let err = workers2[0]
            .to_leader
            .send(Message::ResyncRequest { worker: 0 })
            .expect_err("send to a dropped parent must fail");
        assert!(format!("{err}").contains("root"), "{err}");
    }

    #[test]
    fn tree_wires_every_level_and_names_relay_peers() {
        // n=4, fanout=2, depth=2: root -> 2 relays -> 4 workers.
        let plan = Topology::Tree { fanout: 2, depth: Some(2) }.plan(4).unwrap();
        let (leader, relays, workers) = tree(&plan);
        assert_eq!(leader.child_ids, vec![4, 5]);
        assert_eq!(relays.len(), 2);
        assert_eq!(workers.len(), 4);
        assert_eq!(leader.to_workers[0].peer(), "relay-0");
        assert_eq!(relays[0].down.to_workers[1].peer(), "worker-1");
        assert_eq!(relays[1].up.to_leader.peer(), "root");
        assert_eq!(workers[3].to_leader.peer(), "relay-1");
        assert_eq!(relays[0].child_leaves, vec![1, 1]);
        assert_eq!(relays[0].n_leaves, 2);

        // root -> relay-1 -> worker 3 -> relay-1 -> root, end to end
        leader.to_workers[1]
            .send(Message::Params { round: 7, data: vec![1.0; 2] })
            .unwrap();
        let got = relays[1].up.from_leader.recv().unwrap();
        assert!(matches!(got, Message::Params { round: 7, .. }));
        relays[1].down.to_workers[1].send(got).unwrap();
        match workers[3].from_leader.recv().unwrap() {
            Message::Params { round: 7, data } => assert_eq!(data.len(), 2),
            other => panic!("unexpected {other:?}"),
        }
        workers[3]
            .to_leader
            .send(Message::SparseUpdate {
                round: 7,
                worker: 3,
                payload: vec![0u8; 9],
                loss: 0.5,
                examples: 1,
                mem_norm: 0.0,
                participants: 1,
            })
            .unwrap();
        match relays[1].down.from_workers.recv().unwrap() {
            Message::SparseUpdate { worker: 3, .. } => {}
            other => panic!("unexpected {other:?}"),
        }
        relays[1]
            .up
            .to_leader
            .send(Message::SparseUpdate {
                round: 7,
                worker: 5,
                payload: vec![0u8; 11],
                loss: 0.5,
                examples: 2,
                mem_norm: 0.0,
                participants: 2,
            })
            .unwrap();
        match leader.from_workers.recv().unwrap() {
            Message::SparseUpdate { worker: 5, participants: 2, .. } => {}
            other => panic!("unexpected {other:?}"),
        }
        // per-level accounting: each hop only counted on its own links
        assert_eq!(leader.down_stats[1].snapshot(), (1, 8));
        assert_eq!(relays[1].down.down_stats[1].snapshot(), (1, 8));
        assert_eq!(relays[1].down.up_stats[1].snapshot(), (1, 9));
        assert_eq!(leader.up_stats[1].snapshot(), (1, 11));
        assert_eq!(leader.up_stats[0].snapshot(), (0, 0));
    }

    #[test]
    fn depth1_tree_wiring_is_star_wiring() {
        let plan = Topology::Tree { fanout: 3, depth: Some(1) }.plan(3).unwrap();
        let (leader, relays, workers) = tree(&plan);
        assert!(relays.is_empty());
        assert_eq!(workers.len(), 3);
        assert_eq!(leader.child_ids, vec![0, 1, 2]);
        for (i, w) in workers.iter().enumerate() {
            assert_eq!(w.id, i);
            assert_eq!(w.to_leader.peer(), "root");
            assert_eq!(leader.to_workers[i].peer(), format!("worker-{i}"));
        }
    }
}
