//! Simulated cluster transport with exact byte accounting.
//!
//! The paper's experiments measure accuracy at a fixed *communication
//! budget*, not wall-clock network time, so the default transport is
//! in-process: one channel pair per worker plus a broadcast path, with
//! every payload's byte length recorded on per-link counters. The TCP
//! transport in [`super::tcp`] implements the same trait for multi-process
//! runs; integration tests assert the two produce identical traffic.
//!
//! Accounting convention: per-worker unicasts (dense params, resyncs,
//! worker updates) count once per link; the encode-once broadcast frame
//! ([`Message::ParamsDelta`], shared via `Arc`) counts ONCE on
//! [`LeaderEndpoints::bcast_stats`] regardless of n — it models a
//! broadcast/multicast domain carrying one frame, and both transports
//! apply the same convention so their measured bytes agree.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Duration;

/// Messages exchanged between leader and workers each round.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Leader -> workers: full model broadcast (round t's omega). The
    /// dense fallback of the delta downlink: round 0, periodic resyncs,
    /// and on-demand [`Message::ResyncRequest`] replies.
    Params { round: u64, data: Vec<f32> },
    /// Leader -> workers: encoded sparse param delta omega^t - omega^{t-1}
    /// (codec bytes). Encoded once and shared across all workers — the
    /// `Arc` payload IS the encode-once broadcast frame.
    ParamsDelta { round: u64, payload: Arc<[u8]> },
    /// Worker -> leader: encoded sparse update (codec bytes) plus the
    /// worker's round loss and residual-memory norm (metrics side-band).
    SparseUpdate {
        round: u64,
        worker: usize,
        payload: Vec<u8>,
        loss: f32,
        examples: u64,
        mem_norm: f32,
    },
    /// Worker -> leader: "I cannot apply a delta (no base params); unicast
    /// me a dense `Params` frame for this round." Control-plane only.
    ResyncRequest { worker: usize },
    /// Worker -> leader: this worker hit a fatal error and is exiting.
    /// Without it a FullSync gather would block forever on a quorum that
    /// can never complete (the other workers keep the channel open); the
    /// leader aborts the round instead and the cluster surfaces the
    /// worker's own error. Control-plane only.
    WorkerFailed { worker: usize },
    /// Leader -> workers: shut down cleanly.
    Shutdown,
}

impl Message {
    /// Wire size in bytes, as a real network would see it (payload only;
    /// we deliberately exclude per-message framing, matching how the paper
    /// counts "number of gradients communicated"). Control messages
    /// (shutdown, resync requests) cost nothing under that accounting.
    pub fn wire_bytes(&self) -> u64 {
        match self {
            Message::Params { data, .. } => 4 * data.len() as u64,
            Message::ParamsDelta { payload, .. } => payload.len() as u64,
            Message::SparseUpdate { payload, .. } => payload.len() as u64,
            Message::ResyncRequest { .. } => 0,
            Message::WorkerFailed { .. } => 0,
            Message::Shutdown => 0,
        }
    }
}

/// Byte counters for one direction of one link.
#[derive(Debug, Default)]
pub struct LinkStats {
    pub messages: AtomicU64,
    pub bytes: AtomicU64,
}

impl LinkStats {
    fn record(&self, bytes: u64) {
        self.messages.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> (u64, u64) {
        (self.messages.load(Ordering::Relaxed), self.bytes.load(Ordering::Relaxed))
    }
}

/// A counted sender: records bytes on the shared link stats, then sends.
/// Clones share the same channel and counters (the cluster keeps one
/// aside per worker thread to report fatal worker errors).
#[derive(Clone)]
pub struct CountedSender {
    tx: Sender<Message>,
    stats: Arc<LinkStats>,
}

impl CountedSender {
    pub fn new(tx: Sender<Message>, stats: Arc<LinkStats>) -> Self {
        CountedSender { tx, stats }
    }

    pub fn send(&self, msg: Message) -> anyhow::Result<()> {
        self.stats.record(msg.wire_bytes());
        self.tx
            .send(msg)
            .map_err(|_| anyhow::anyhow!("peer hung up"))
    }

    /// Deliver without touching this link's counters. Used by the
    /// encode-once broadcast path, whose single shared frame is recorded
    /// once on [`LeaderEndpoints::bcast_stats`] instead of once per link.
    pub fn send_uncounted(&self, msg: Message) -> anyhow::Result<()> {
        self.tx
            .send(msg)
            .map_err(|_| anyhow::anyhow!("peer hung up"))
    }
}

/// Endpoints the leader holds.
pub struct LeaderEndpoints {
    /// Broadcast senders, one per worker (uplink stats shared).
    pub to_workers: Vec<CountedSender>,
    /// Single merged receiver for worker updates.
    pub from_workers: Receiver<Message>,
    /// Downlink (leader->worker) unicast traffic, per worker.
    pub down_stats: Vec<Arc<LinkStats>>,
    /// Uplink (worker->leader) traffic, per worker.
    pub up_stats: Vec<Arc<LinkStats>>,
    /// Shared-frame broadcast traffic: an encode-once frame delivered to
    /// every worker is recorded here exactly once (a broadcast medium /
    /// multicast egress carries it once), while per-worker unicasts (dense
    /// fallbacks, resyncs) stay on [`Self::down_stats`].
    pub bcast_stats: Arc<LinkStats>,
}

impl LeaderEndpoints {
    /// Block for the next worker→leader message. Errors when every worker
    /// sender has hung up.
    pub fn recv(&self) -> anyhow::Result<Message> {
        self.from_workers
            .recv()
            .map_err(|_| anyhow::anyhow!("worker channel closed"))
    }

    /// Wait up to `timeout` for the next worker→leader message; `Ok(None)`
    /// on timeout. Both transports support this: the in-process star is a
    /// channel, and the TCP bridge forwards socket reads into the same
    /// channel — so a quorum gather's drain deadline behaves identically
    /// on either wire.
    pub fn recv_timeout(&self, timeout: Duration) -> anyhow::Result<Option<Message>> {
        match self.from_workers.recv_timeout(timeout) {
            Ok(m) => Ok(Some(m)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => Err(anyhow::anyhow!("worker channel closed")),
        }
    }

    /// Send one shared encoded frame to every worker, recording its bytes
    /// once on the broadcast counter — the encode-once broadcast path.
    pub fn broadcast_shared(&self, round: u64, payload: Arc<[u8]>) -> anyhow::Result<()> {
        self.bcast_stats.record(payload.len() as u64);
        for tx in &self.to_workers {
            tx.send_uncounted(Message::ParamsDelta { round, payload: payload.clone() })?;
        }
        Ok(())
    }

    /// Total (messages, bytes) the downlink carried: per-worker unicasts
    /// plus shared broadcast frames.
    pub fn downlink_total(&self) -> (u64, u64) {
        let (m, b) = total(&self.down_stats);
        let (bm, bb) = self.bcast_stats.snapshot();
        (m + bm, b + bb)
    }
}

/// Endpoints one worker holds.
pub struct WorkerEndpoints {
    pub id: usize,
    pub from_leader: Receiver<Message>,
    pub to_leader: CountedSender,
}

/// Build an in-process star topology with `n` workers.
pub fn star(n: usize) -> (LeaderEndpoints, Vec<WorkerEndpoints>) {
    let (up_tx, up_rx) = channel::<Message>();
    let mut to_workers = Vec::with_capacity(n);
    let mut workers = Vec::with_capacity(n);
    let mut down_stats = Vec::with_capacity(n);
    let mut up_stats = Vec::with_capacity(n);
    for id in 0..n {
        let (down_tx, down_rx) = channel::<Message>();
        let down = Arc::new(LinkStats::default());
        let up = Arc::new(LinkStats::default());
        to_workers.push(CountedSender::new(down_tx, down.clone()));
        workers.push(WorkerEndpoints {
            id,
            from_leader: down_rx,
            to_leader: CountedSender::new(up_tx.clone(), up.clone()),
        });
        down_stats.push(down);
        up_stats.push(up);
    }
    (
        LeaderEndpoints {
            to_workers,
            from_workers: up_rx,
            down_stats,
            up_stats,
            bcast_stats: Arc::new(LinkStats::default()),
        },
        workers,
    )
}

/// Total (messages, bytes) across a set of link stats.
pub fn total(stats: &[Arc<LinkStats>]) -> (u64, u64) {
    stats.iter().fold((0, 0), |(m, b), s| {
        let (sm, sb) = s.snapshot();
        (m + sm, b + sb)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn star_delivers_both_directions() {
        let (leader, workers) = star(3);
        for (i, tx) in leader.to_workers.iter().enumerate() {
            tx.send(Message::Params { round: 1, data: vec![i as f32; 4] }).unwrap();
        }
        let handles: Vec<_> = workers
            .into_iter()
            .map(|w| {
                std::thread::spawn(move || {
                    let msg = w.from_leader.recv().unwrap();
                    match msg {
                        Message::Params { round, data } => {
                            assert_eq!(round, 1);
                            assert_eq!(data[0], w.id as f32);
                        }
                        _ => panic!("unexpected message"),
                    }
                    w.to_leader
                        .send(Message::SparseUpdate {
                            round: 1,
                            worker: w.id,
                            payload: vec![0u8; 10 + w.id],
                            loss: 0.5,
                            examples: 8,
                            mem_norm: 0.0,
                        })
                        .unwrap();
                })
            })
            .collect();
        let mut seen = std::collections::HashSet::new();
        for _ in 0..3 {
            match leader.from_workers.recv().unwrap() {
                Message::SparseUpdate { worker, .. } => {
                    seen.insert(worker);
                }
                _ => panic!("unexpected"),
            }
        }
        assert_eq!(seen.len(), 3);
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn byte_accounting_exact() {
        let (leader, workers) = star(2);
        leader.to_workers[0]
            .send(Message::Params { round: 0, data: vec![0.0; 100] })
            .unwrap();
        workers[0]
            .to_leader
            .send(Message::SparseUpdate {
                round: 0,
                worker: 0,
                payload: vec![0u8; 37],
                loss: 0.0,
                examples: 1,
                mem_norm: 0.0,
            })
            .unwrap();
        assert_eq!(leader.down_stats[0].snapshot(), (1, 400));
        assert_eq!(leader.up_stats[0].snapshot(), (1, 37));
        assert_eq!(leader.down_stats[1].snapshot(), (0, 0));
        let (msgs, bytes) = total(&leader.down_stats);
        assert_eq!((msgs, bytes), (1, 400));
    }

    #[test]
    fn shutdown_costs_nothing() {
        assert_eq!(Message::Shutdown.wire_bytes(), 0);
        assert_eq!(Message::ResyncRequest { worker: 3 }.wire_bytes(), 0);
        assert_eq!(Message::WorkerFailed { worker: 1 }.wire_bytes(), 0);
    }

    #[test]
    fn broadcast_shared_counts_frame_once() {
        let (leader, workers) = star(3);
        let frame: Arc<[u8]> = vec![0u8; 64].into();
        leader.broadcast_shared(5, frame).unwrap();
        // every worker receives the same frame...
        for w in &workers {
            match w.from_leader.recv().unwrap() {
                Message::ParamsDelta { round, payload } => {
                    assert_eq!(round, 5);
                    assert_eq!(payload.len(), 64);
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        // ...but the wire carried it exactly once.
        assert_eq!(leader.bcast_stats.snapshot(), (1, 64));
        assert_eq!(total(&leader.down_stats), (0, 0));
        assert_eq!(leader.downlink_total(), (1, 64));
        // a dense unicast on top still lands on the per-link counters
        leader.to_workers[1]
            .send(Message::Params { round: 5, data: vec![0.0; 10] })
            .unwrap();
        assert_eq!(leader.down_stats[1].snapshot(), (1, 40));
        assert_eq!(leader.downlink_total(), (2, 104));
    }

    #[test]
    fn recv_timeout_times_out_then_delivers() {
        let (leader, workers) = star(1);
        // empty queue: timeout yields Ok(None), not an error
        assert!(leader
            .recv_timeout(Duration::from_millis(5))
            .unwrap()
            .is_none());
        workers[0]
            .to_leader
            .send(Message::ResyncRequest { worker: 0 })
            .unwrap();
        match leader.recv_timeout(Duration::from_millis(100)).unwrap() {
            Some(Message::ResyncRequest { worker: 0 }) => {}
            other => panic!("unexpected {other:?}"),
        }
        // all senders gone: disconnected is a hard error on both recvs
        drop(workers);
        assert!(leader.recv_timeout(Duration::from_millis(1)).is_err());
        assert!(leader.recv().is_err());
    }

    #[test]
    fn send_uncounted_leaves_counters_alone() {
        let (leader, workers) = star(1);
        leader.to_workers[0]
            .send_uncounted(Message::Params { round: 0, data: vec![1.0; 8] })
            .unwrap();
        assert_eq!(leader.down_stats[0].snapshot(), (0, 0));
        assert!(matches!(
            workers[0].from_leader.recv().unwrap(),
            Message::Params { .. }
        ));
    }
}
