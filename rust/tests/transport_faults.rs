//! Transport fault-injection suite: a socket that dies mid-round — clean
//! kill, half-close, mid-frame EOF, or corrupt bytes — must ERROR the
//! cluster within bounded time, naming the dead hop, never deadlock it.
//! Runs the same faults against BOTH wire transports (the legacy
//! thread-per-connection bridge and the evented reactor) on star and tree
//! topologies, under both gather policies.
//!
//! The harness drives the cluster manually (leader / relay / worker
//! threads over *tapped* topology builders) so one child's socket is
//! handed to the test raw instead of being bridged into endpoints; the
//! fault thread then misbehaves on the real wire. Every wait is bounded:
//! the leader's verdict arrives over a channel guarded by `recv_timeout`,
//! so no fault path can hang CI.

use std::io::Write;
use std::net::TcpStream;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

use rtopk::comms::evented::{evented_star_tapped, evented_tree_tapped};
use rtopk::comms::tcp::{read_message, tcp_star_tapped, tcp_tree_tapped, write_message, ChildSide};
use rtopk::comms::transport::{LeaderEndpoints, RelayEndpoints, WorkerEndpoints};
use rtopk::comms::{Message, TreePlan};
use rtopk::coordinator::leader::run_leader;
use rtopk::coordinator::worker::run_worker;
use rtopk::coordinator::{
    mock_worker_factory, run_relay, OptimKind, RelayStats, TrainConfig, WorkerFactory,
};
use rtopk::optim::LrSchedule;
use rtopk::sparsify::SparsifierKind;
use rtopk::util::rng::Rng;

const DIM: usize = 64;
/// Upper bound on "the cluster notices a dead link". Generous for CI —
/// the point is that it is FINITE; healthy runs report in well under a
/// second.
const WAIT: Duration = Duration::from_secs(30);

type StarBuild = fn(usize, &[usize]) -> anyhow::Result<(LeaderEndpoints, Vec<ChildSide>)>;
#[allow(clippy::type_complexity)]
type TreeBuild = fn(
    &TreePlan,
    &[usize],
) -> anyhow::Result<(
    LeaderEndpoints,
    Vec<RelayEndpoints>,
    Vec<Option<WorkerEndpoints>>,
    Vec<(usize, TcpStream)>,
)>;

fn quick_cfg(nodes: usize, rounds: u64) -> TrainConfig {
    let mut cfg = TrainConfig::image_default(nodes, SparsifierKind::TopK, 0.9);
    cfg.rounds = rounds;
    cfg.warmup_epochs = 0.0;
    cfg.optim = OptimKind::Sgd { clip: None };
    cfg.lr = LrSchedule::constant(0.3);
    cfg.eval_every = rounds;
    cfg
}

// ---- the faults ----------------------------------------------------------

/// Clean kill: the peer process vanished (socket closed mid-round).
fn inject_kill(sock: TcpStream) {
    drop(sock);
}

/// Half-close: FIN on the write side while the read side stays open and
/// keeps consuming — the sneakiest variant, the link LOOKS alive to
/// anything that only writes. Draining until error keeps the parent's
/// writer unblocked; the read timeout bounds the drain.
fn inject_half_close(sock: TcpStream) {
    sock.shutdown(std::net::Shutdown::Write).expect("half-close the socket");
    sock.set_read_timeout(Some(Duration::from_secs(10))).expect("bound the drain");
    let mut r = &sock;
    while read_message(&mut r).is_ok() {}
}

/// Mid-frame EOF: a valid frame header goes out, then the stream dies
/// before the body completes.
fn inject_midframe_eof(mut sock: TcpStream) {
    let mut frame = Vec::new();
    write_message(
        &mut frame,
        &Message::SparseUpdate {
            round: 0,
            worker: 0,
            payload: vec![7u8; 64],
            loss: 0.0,
            examples: 1,
            mem_norm: 0.0,
            participants: 1,
        },
    )
    .expect("encode a well-formed frame");
    sock.write_all(&frame[..frame.len() / 2]).expect("send the truncated half");
}

/// Corrupt tag mid-stream: line noise / a buggy peer desyncs the framing.
fn inject_corrupt_tag(mut sock: TcpStream) {
    sock.write_all(&[0xFF; 16]).expect("send garbage bytes");
}

// ---- the harness ---------------------------------------------------------

fn spawn_worker(
    w: WorkerEndpoints,
    factory: &WorkerFactory,
    cfg: &TrainConfig,
) -> std::thread::JoinHandle<()> {
    let factory = factory.clone();
    let cfg = cfg.clone();
    std::thread::spawn(move || {
        let setup = factory(w.id).expect("mock setup");
        let rng = Rng::new(cfg.seed).fork(1_000 + w.id as u64);
        // errors here are the cascade of the injected fault, not a verdict
        let _ = run_worker(w, setup, &cfg, rng);
    })
}

/// Run the leader on its own thread so the test thread can bound the wait;
/// on any exit, push Shutdown to every child so healthy subtrees unblock.
fn spawn_leader(
    leader: LeaderEndpoints,
    cfg: &TrainConfig,
) -> std::sync::mpsc::Receiver<anyhow::Result<()>> {
    let (done_tx, done_rx) = channel();
    let cfg = cfg.clone();
    std::thread::spawn(move || {
        let res = run_leader(&leader, vec![0.0; DIM], None, &cfg, "fault-itest", 8);
        for tx in &leader.to_workers {
            let _ = tx.send(Message::Shutdown);
        }
        let _ = done_tx.send(res.map(|_| ()));
    });
    done_rx
}

/// Star: child `tap`'s socket goes to `inject`; the leader must error
/// within WAIT naming that worker.
fn star_fault_errors_leader(build: StarBuild, gather: &str, inject: fn(TcpStream)) {
    let nodes = 3;
    let tap = 2;
    let mut cfg = quick_cfg(nodes, 6);
    cfg.set_gather(gather).unwrap();
    let (leader, sides) = build(nodes, &[tap]).unwrap();
    let factory = mock_worker_factory(DIM, 0.05, 8);
    let mut joins = Vec::new();
    for side in sides {
        match side {
            ChildSide::Bridged(w) => joins.push(spawn_worker(w, &factory, &cfg)),
            ChildSide::Raw(sock) => joins.push(std::thread::spawn(move || inject(sock))),
        }
    }
    let done_rx = spawn_leader(leader, &cfg);
    let res = done_rx.recv_timeout(WAIT).expect("leader must give a verdict in bounded time");
    let err = res.expect_err("a dead link must error the run, not complete it");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("worker-2 reported a fatal error"),
        "error must name the dead hop: {msg}"
    );
    for j in joins {
        j.join().expect("no fault thread may panic");
    }
}

/// Tree (`fanout=2,depth=2`, n=4): leaf worker 0's socket goes to
/// `inject`. Its relay must error naming worker-0, and the failure must
/// climb to the root as relay-0's — the two-hop supervision chain.
fn tree_fault_errors_cluster(build: TreeBuild, gather: &str, inject: fn(TcpStream)) {
    let nodes = 4;
    let mut cfg = quick_cfg(nodes, 6);
    cfg.set_topology("tree:fanout=2,depth=2").unwrap();
    cfg.set_gather(gather).unwrap();
    let plan = cfg.topology.plan(nodes).unwrap();
    let (leader, relays, workers, raw) = build(&plan, &[0]).unwrap();
    let factory = mock_worker_factory(DIM, 0.05, 8);
    let mut joins = Vec::new();
    // relay threads with the cluster's guard semantics inlined: on error,
    // report WorkerFailed up and Shutdown down
    let (relay_err_tx, relay_err_rx) = channel::<String>();
    for r in relays {
        let cfg = cfg.clone();
        let up = r.up.to_leader.clone();
        let down = r.down.to_workers.clone();
        let rid = r.id;
        let stats = Arc::new(RelayStats::new(r.level));
        let etx = relay_err_tx.clone();
        joins.push(std::thread::spawn(move || {
            if let Err(e) = run_relay(r, &cfg, stats) {
                let _ = etx.send(format!("{e:#}"));
                let _ = up.send(Message::WorkerFailed { worker: rid });
                for tx in &down {
                    let _ = tx.send(Message::Shutdown);
                }
            }
        }));
    }
    drop(relay_err_tx);
    for w in workers.into_iter().flatten() {
        joins.push(spawn_worker(w, &factory, &cfg));
    }
    for (_id, sock) in raw {
        joins.push(std::thread::spawn(move || inject(sock)));
    }
    let done_rx = spawn_leader(leader, &cfg);
    let res = done_rx.recv_timeout(WAIT).expect("leader must give a verdict in bounded time");
    let err = res.expect_err("a dead leaf link must error the run, not complete it");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("relay-0 reported a fatal error"),
        "the root names its failed DIRECT child: {msg}"
    );
    let relay_msg =
        relay_err_rx.recv_timeout(WAIT).expect("relay-0 must have reported its own error");
    assert!(
        relay_msg.contains("worker-0 reported a fatal error"),
        "the relay names the dead leaf: {relay_msg}"
    );
    for j in joins {
        j.join().expect("no node or fault thread may panic");
    }
}

// ---- the matrix ----------------------------------------------------------

const FULL: &str = "full";
const QUORUM: &str = "quorum:m=2,timeout_ms=50";
const QUORUM_TREE: &str = "quorum:m=3,timeout_ms=50";

#[test]
fn star_socket_kill_errors_fullsync_legacy() {
    star_fault_errors_leader(tcp_star_tapped, FULL, inject_kill);
}

#[test]
fn star_socket_kill_errors_fullsync_evented() {
    star_fault_errors_leader(evented_star_tapped, FULL, inject_kill);
}

#[test]
fn star_socket_kill_errors_quorum_legacy() {
    // The quorum CAN close without the dead worker — WorkerFailed must
    // still abort the run instead of silently training on forever with a
    // vanished peer.
    star_fault_errors_leader(tcp_star_tapped, QUORUM, inject_kill);
}

#[test]
fn star_socket_kill_errors_quorum_evented() {
    star_fault_errors_leader(evented_star_tapped, QUORUM, inject_kill);
}

#[test]
fn star_half_close_errors_legacy() {
    star_fault_errors_leader(tcp_star_tapped, FULL, inject_half_close);
}

#[test]
fn star_half_close_errors_evented() {
    star_fault_errors_leader(evented_star_tapped, FULL, inject_half_close);
}

#[test]
fn star_midframe_eof_errors_legacy() {
    star_fault_errors_leader(tcp_star_tapped, FULL, inject_midframe_eof);
}

#[test]
fn star_midframe_eof_errors_evented() {
    star_fault_errors_leader(evented_star_tapped, FULL, inject_midframe_eof);
}

#[test]
fn star_corrupt_tag_errors_legacy() {
    star_fault_errors_leader(tcp_star_tapped, FULL, inject_corrupt_tag);
}

#[test]
fn star_corrupt_tag_errors_evented() {
    star_fault_errors_leader(evented_star_tapped, FULL, inject_corrupt_tag);
}

#[test]
fn tree_socket_kill_errors_fullsync_legacy() {
    tree_fault_errors_cluster(tcp_tree_tapped, FULL, inject_kill);
}

#[test]
fn tree_socket_kill_errors_fullsync_evented() {
    tree_fault_errors_cluster(evented_tree_tapped, FULL, inject_kill);
}

#[test]
fn tree_corrupt_tag_errors_quorum_legacy() {
    tree_fault_errors_cluster(tcp_tree_tapped, QUORUM_TREE, inject_corrupt_tag);
}

#[test]
fn tree_corrupt_tag_errors_quorum_evented() {
    tree_fault_errors_cluster(evented_tree_tapped, QUORUM_TREE, inject_corrupt_tag);
}
