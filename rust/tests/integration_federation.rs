//! Integration tests for the federation subsystem: fixed-membership
//! bit-identity, bitwise reproducibility across reruns / transports /
//! topologies, population-independence of round cost, availability +
//! quorum composition, and EF-eviction accounting.

use rtopk::coordinator::{
    self, mock_client_factory, mock_worker_factory, ClientEfPolicy, FederationConfig, OptimKind,
    SamplerKind, TrainConfig,
};
use rtopk::optim::LrSchedule;
use rtopk::runtime::{Batch, MockModel, ModelRuntime};
use rtopk::sparsify::SparsifierKind;

fn fed_cfg(population: usize, cohort: usize, pool: usize, rounds: u64) -> TrainConfig {
    let mut cfg = TrainConfig::image_default(pool, SparsifierKind::TopK, 0.9);
    cfg.rounds = rounds;
    cfg.warmup_epochs = 0.0;
    cfg.optim = OptimKind::Sgd { clip: None };
    cfg.lr = LrSchedule::constant(0.2);
    cfg.eval_every = rounds;
    cfg.subsample_ratio = 1.0 / cohort as f64;
    let mut fed = FederationConfig::new(population, cohort, pool);
    fed.population_seed = cfg.seed;
    cfg.federation = Some(fed);
    cfg
}

fn run_fed(
    cfg: &TrainConfig,
    dim: usize,
    transport: coordinator::Transport,
) -> coordinator::ClusterResult {
    let model = MockModel::new(dim, 0.05, 42);
    coordinator::run_with(
        cfg,
        "federation-itest",
        model.init_params(),
        mock_client_factory(dim, 0.05, 8),
        Box::new(|| Ok(None)),
        transport,
    )
    .unwrap()
}

/// The fixed-membership invariant: with `federation: None` (the only mode
/// the presets construct) the cluster must reproduce the classic
/// distributed trajectory BITWISE — here pinned against a local replica of
/// 2-worker baseline SGD, exactly the pre-federation equivalence — and the
/// metrics must carry no federation block.
#[test]
fn fixed_membership_path_is_bit_identical_to_pre_federation_run() {
    let dim = 64;
    let mut cfg = TrainConfig::image_default(2, SparsifierKind::Baseline, 0.0);
    cfg.rounds = 10;
    cfg.warmup_epochs = 0.0;
    cfg.optim = OptimKind::Sgd { clip: None };
    cfg.lr = LrSchedule::constant(0.3);
    cfg.eval_every = 30;
    assert!(cfg.federation.is_none());
    let res = coordinator::run(
        &cfg,
        "fixed-membership",
        vec![0.0; dim],
        mock_worker_factory(dim, 0.1, 8),
        Box::new(|| Ok(None)),
    )
    .unwrap();
    assert!(res.metrics.federation.is_none(), "no federation block without --clients");
    // local replica: average gradient of the two mock workers
    let mut m0 = MockModel::new(dim, 0.1, 42);
    let mut params = vec![0.0f32; dim];
    let (mut c0, mut c1) = (0u64, 1_000_000u64);
    let mut g0 = Vec::new();
    let mut g1 = Vec::new();
    for _ in 0..10 {
        c0 += 1;
        c1 += 1;
        m0.train_step(&params, &Batch::Seed(c0), &mut g0).unwrap();
        m0.train_step(&params, &Batch::Seed(c1), &mut g1).unwrap();
        for ((w, &a), &b) in params.iter_mut().zip(&g0).zip(&g1) {
            *w -= 0.3 * 0.5 * (a + b);
        }
    }
    for (a, b) in res.params.iter().zip(&params) {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "fixed-membership run must equal the pre-federation trajectory bitwise"
        );
    }
}

/// A federated run is a pure function of its seeds: rerunning it — on
/// either transport — must give the same cohorts, the same folded frames,
/// and bit-identical parameters, and it must actually converge.
#[test]
fn federated_run_is_bitwise_reproducible_across_reruns_and_transports_tcp() {
    let dim = 512;
    let rounds = 40;
    let cfg = fed_cfg(2_000, 16, 4, rounds);
    let a = run_fed(&cfg, dim, coordinator::Transport::InProcess);
    let b = run_fed(&cfg, dim, coordinator::Transport::InProcess);
    let c = run_fed(&cfg, dim, coordinator::Transport::Tcp);
    for (x, y) in a.params.iter().zip(&b.params) {
        assert_eq!(x.to_bits(), y.to_bits(), "rerun must be bitwise identical");
    }
    for (x, y) in a.params.iter().zip(&c.params) {
        assert_eq!(x.to_bits(), y.to_bits(), "transports must agree bitwise");
    }
    // the folded federation summaries agree too (same cohorts, same
    // participation maps, same eviction counts)
    assert_eq!(a.metrics.federation, b.metrics.federation);
    assert_eq!(a.metrics.federation, c.metrics.federation);
    let fs = a.metrics.federation.as_ref().unwrap();
    assert_eq!(fs.scheduled, rounds * 16, "uniform sampler schedules the full cohort");
    assert_eq!(fs.reported, fs.scheduled, "no availability model: everyone reports");
    assert!(fs.distinct_clients >= 16 && fs.distinct_clients <= (rounds as usize) * 16);
    assert_eq!(fs.participation_hist.iter().sum::<u64>() as usize, fs.distinct_clients);
    // every round folds the whole cohort
    for r in &a.metrics.records {
        assert_eq!(r.participants, 16, "round {}: cohort-sized participation", r.round);
    }
    // and the thing converges
    let model = MockModel::new(dim, 0.05, 42);
    let d0 = model.distance_sq(&model.init_params());
    let d1 = model.distance_sq(&a.params);
    assert!(d1 < 0.35 * d0, "federated run must converge: {d0} -> {d1}");
}

/// The same federated round routed through a relay tree: pool slots are
/// the leaves, relays fold slot frames (which already fold cohort shares),
/// and the run stays deterministic across reruns and wires.
#[test]
fn federated_tree_topology_is_reproducible_on_both_transports_tcp() {
    let dim = 256;
    let mut cfg = fed_cfg(2_000, 16, 8, 15);
    cfg.set_topology("tree:fanout=4,depth=2").unwrap();
    let a = run_fed(&cfg, dim, coordinator::Transport::InProcess);
    let b = run_fed(&cfg, dim, coordinator::Transport::InProcess);
    let c = run_fed(&cfg, dim, coordinator::Transport::Tcp);
    assert_eq!(a.params, b.params, "federated tree rerun must be reproducible");
    assert_eq!(a.params, c.params, "federated tree transports must agree");
    assert_eq!(a.metrics.federation, c.metrics.federation);
    // participants stay in CLIENT units through the relay fold
    for r in &a.metrics.records {
        assert_eq!(r.participants, 16, "round {}: relays preserve client counts", r.round);
    }
    assert_eq!(a.metrics.relay_levels.len(), 1, "one relay level folds the slots");
}

/// The acceptance bound: a 10× larger registered population at a fixed
/// cohort must not change what a round touches — same schedule volume,
/// same per-round participation, and wall time in the same regime (the
/// round loop never walks the population).
#[test]
fn round_cost_is_independent_of_population_size() {
    let dim = 4096;
    let rounds = 10;
    let small = run_fed(&fed_cfg(10_000, 32, 8, rounds), dim, coordinator::Transport::InProcess);
    let large = run_fed(&fed_cfg(100_000, 32, 8, rounds), dim, coordinator::Transport::InProcess);
    for res in [&small, &large] {
        let fs = res.metrics.federation.as_ref().unwrap();
        assert_eq!(fs.scheduled, rounds * 32);
        assert_eq!(fs.reported, rounds * 32);
        assert!(fs.distinct_clients <= (rounds as usize) * 32);
        for r in &res.metrics.records {
            assert_eq!(r.participants, 32);
        }
    }
    let wall = |res: &coordinator::ClusterResult| {
        res.metrics.records.iter().map(|r| r.wall_ms).sum::<f64>()
    };
    let (w_small, w_large) = (wall(&small), wall(&large));
    // generous bound: scheduling O(population) work per round would blow
    // past 10× immediately; genuine O(cohort) rounds sit near 1× modulo
    // CI timing noise
    assert!(
        w_large < 10.0 * w_small.max(1.0),
        "round cost must not scale with population: 10^4 clients took {w_small:.1} ms, \
         10^5 took {w_large:.1} ms"
    );
}

/// Availability sampling composes with the quorum gather: scheduled
/// clients that never report shrink the folded frames, empty slot frames
/// still close the gather, and the run stays deterministic and healthy.
#[test]
fn availability_model_composes_with_quorum_and_stays_deterministic() {
    let dim = 256;
    let rounds = 20;
    let mut cfg = fed_cfg(2_000, 16, 4, rounds);
    cfg.federation.as_mut().unwrap().sampler = SamplerKind::Availability { p: 0.6 };
    cfg.set_gather("quorum:m=4,timeout_ms=50").unwrap();
    let a = run_fed(&cfg, dim, coordinator::Transport::InProcess);
    let b = run_fed(&cfg, dim, coordinator::Transport::InProcess);
    assert_eq!(a.params, b.params, "availability coins are seeded, not wall-clock");
    let fs = a.metrics.federation.as_ref().unwrap();
    assert_eq!(fs.scheduled, rounds * 16);
    assert!(
        fs.reported < fs.scheduled,
        "p=0.6 must lose some scheduled clients ({} of {})",
        fs.reported,
        fs.scheduled
    );
    assert!(fs.reported > 0, "p=0.6 cannot silence everyone over {rounds} rounds");
    // per-round participation equals that round's reporting clients
    let from_records: u64 =
        a.metrics.records.iter().map(|r| r.participants as u64).sum();
    assert_eq!(from_records, fs.reported);
}

/// EF-store policies surface in the folded metrics: a tight cap must
/// evict (and count it), `off` must not, and the eviction pressure shows
/// up in the summary JSON consumers read.
#[test]
fn ef_eviction_policies_surface_in_metrics() {
    let dim = 128;
    let rounds = 12;
    let mut cfg = fed_cfg(500, 16, 2, rounds);
    cfg.federation.as_mut().unwrap().client_ef = ClientEfPolicy::Evict { cap: Some(2) };
    let tight = run_fed(&cfg, dim, coordinator::Transport::InProcess);
    let fs = tight.metrics.federation.as_ref().unwrap();
    // each slot folds ~8 fresh clients per round into a 2-entry store
    assert!(fs.ef_evictions > 0, "cap=2 under 8 clients/slot/round must evict");
    assert_eq!(fs.client_ef, "evict:cap=2");
    let mut cfg_off = fed_cfg(500, 16, 2, rounds);
    cfg_off.federation.as_mut().unwrap().client_ef = ClientEfPolicy::Off;
    let off = run_fed(&cfg_off, dim, coordinator::Transport::InProcess);
    let fs_off = off.metrics.federation.as_ref().unwrap();
    assert_eq!(fs_off.ef_evictions, 0, "no store, no evictions");
    assert_eq!(fs_off.client_ef, "off");
    // the summary JSON carries the block
    let json = tight.metrics.summary_json().to_pretty();
    assert!(json.contains("\"federation\""), "summary must surface federation: {json}");
    assert!(json.contains("ef_evictions"), "{json}");
}

/// The rendered summary JSON — not just the folded struct — must be
/// byte-identical across reruns. This pins the BTreeMap conversions in
/// `FederationStats.participation` and `ClientEfStore.entries`: with
/// hash-ordered maps the participation histogram and eviction counts
/// were fold-order dependent, so the string could flap run to run.
#[test]
fn federation_summary_json_is_byte_identical_across_reruns() {
    let dim = 64;
    let rounds = 10;
    let mut cfg = fed_cfg(500, 16, 4, rounds);
    // a tight EF cap keeps the per-slot store churning, so entry
    // iteration order feeds the eviction counts the summary reports
    cfg.federation.as_mut().unwrap().client_ef = ClientEfPolicy::Evict { cap: Some(2) };
    let a = run_fed(&cfg, dim, coordinator::Transport::InProcess);
    let b = run_fed(&cfg, dim, coordinator::Transport::InProcess);
    let ja = a.metrics.summary_json().to_pretty();
    let jb = b.metrics.summary_json().to_pretty();
    assert!(ja.contains("participation_hist"), "summary must carry the histogram: {ja}");
    assert_eq!(ja, jb, "summary JSON must be byte-identical across reruns");
}

/// Weighted sampling skews cohorts toward the hot tier but still covers
/// the run deterministically end to end.
#[test]
fn weighted_sampler_runs_end_to_end_and_prefers_hot_clients() {
    let dim = 128;
    let rounds = 30;
    let mut cfg = fed_cfg(1_000, 20, 4, rounds);
    cfg.federation.as_mut().unwrap().sampler = SamplerKind::Weighted;
    let a = run_fed(&cfg, dim, coordinator::Transport::InProcess);
    let b = run_fed(&cfg, dim, coordinator::Transport::InProcess);
    assert_eq!(a.params, b.params);
    let fs = a.metrics.federation.as_ref().unwrap();
    assert_eq!(fs.scheduled, rounds * 20);
    // hot tier = first 100 ids at weight 4: expect ~31% of slots vs 10%
    // under uniform; the recomputed cohorts let us count directly
    let fed = cfg.federation.as_ref().unwrap();
    let mut hot = 0usize;
    let mut total = 0usize;
    for round in 0..rounds {
        for c in coordinator::CohortSampler::round_cohort(fed, cfg.seed, round) {
            total += 1;
            hot += usize::from(c < 100);
        }
    }
    let frac = hot as f64 / total as f64;
    assert!(frac > 0.2, "hot-tier fraction {frac} should exceed the uniform 0.1");
}
