//! Integration tests for the tree (hierarchical) aggregation topology:
//! star ≡ tree:fanout=n,depth=1 bit-identity, root-ingress reduction,
//! relay fault paths, and quorum composition with straggling subtrees —
//! over both transports.

use std::sync::Arc;

use rtopk::coordinator::{
    self, mock_worker_factory, OptimKind, StragglerSim, TrainConfig, WorkerFactory,
};
use rtopk::optim::LrSchedule;
use rtopk::runtime::{MockModel, ModelRuntime};
use rtopk::sparsify::SparsifierKind;

fn quick_cfg(method: SparsifierKind, compression: f64, nodes: usize, rounds: u64) -> TrainConfig {
    let mut cfg = TrainConfig::image_default(nodes, method, compression);
    cfg.rounds = rounds;
    cfg.warmup_epochs = 0.0;
    cfg.optim = OptimKind::Sgd { clip: None };
    cfg.lr = LrSchedule::constant(0.3);
    cfg.eval_every = rounds;
    cfg
}

fn run_on(
    cfg: &TrainConfig,
    dim: usize,
    noise: f32,
    transport: coordinator::Transport,
) -> coordinator::ClusterResult {
    let model = MockModel::new(dim, noise, 42);
    coordinator::run_with(
        cfg,
        "topology-itest",
        model.init_params(),
        mock_worker_factory(dim, noise, 8),
        Box::new(|| Ok(None)),
        transport,
    )
    .unwrap()
}

/// The acceptance pin: `tree:fanout=n,depth=1` must be bit-identical to
/// `star` — parameters AND every byte counter, per round, on both wires,
/// in dense and delta downlink modes.
#[test]
fn tree_depth1_is_bit_identical_to_star_on_both_transports_tcp() {
    let dim = 96;
    let nodes = 4;
    for downlink in ["dense", "baseline|bf16|delta"] {
        let mut cfg_star = quick_cfg(SparsifierKind::RTopK, 0.9, nodes, 12);
        cfg_star.set_downlink(downlink).unwrap();
        let mut cfg_tree = cfg_star.clone();
        cfg_tree.set_topology("tree:fanout=4,depth=1").unwrap();
        for transport in [
            coordinator::Transport::InProcess,
            coordinator::Transport::Tcp,
            coordinator::Transport::TcpEvented,
        ] {
            let a = run_on(&cfg_star, dim, 0.1, transport);
            let b = run_on(&cfg_tree, dim, 0.1, transport);
            for (x, y) in a.params.iter().zip(&b.params) {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "star vs tree:fanout=n,depth=1 params must be bitwise equal \
                     (downlink={downlink}, {transport:?})"
                );
            }
            assert_eq!(a.metrics.records.len(), b.metrics.records.len());
            for (ra, rb) in a.metrics.records.iter().zip(&b.metrics.records) {
                assert_eq!(ra.uplink_bytes, rb.uplink_bytes, "round {}", ra.round);
                assert_eq!(ra.uplink_coords, rb.uplink_coords, "round {}", ra.round);
                assert_eq!(ra.downlink_bytes, rb.downlink_bytes, "round {}", ra.round);
                assert_eq!(ra.participants, rb.participants, "round {}", ra.round);
            }
            assert!(b.metrics.relay_levels.is_empty(), "depth-1 trees have no relays");
            assert_eq!(a.metrics.worker_participation, b.metrics.worker_participation);
        }
    }
}

/// A two-level tree must converge, reproduce bitwise across reruns AND
/// transports, and account its relay level.
#[test]
fn two_level_tree_converges_deterministically_on_both_transports_tcp() {
    let dim = 256;
    let nodes = 8;
    let rounds = 30;
    let mut cfg = quick_cfg(SparsifierKind::RTopK, 0.9, nodes, rounds);
    cfg.set_topology("tree:fanout=4,depth=2").unwrap();
    let model = MockModel::new(dim, 0.05, 42);
    let d0 = model.distance_sq(&model.init_params());
    let a = run_on(&cfg, dim, 0.05, coordinator::Transport::InProcess);
    let b = run_on(&cfg, dim, 0.05, coordinator::Transport::InProcess);
    let c = run_on(&cfg, dim, 0.05, coordinator::Transport::Tcp);
    let d = run_on(&cfg, dim, 0.05, coordinator::Transport::TcpEvented);
    assert_eq!(a.params, b.params, "tree runs must be reproducible");
    assert_eq!(a.params, c.params, "transports must agree under a tree");
    assert_eq!(a.params, d.params, "the evented reactor must agree bit-for-bit");
    let d1 = model.distance_sq(&a.params);
    assert!(d1 < 0.1 * d0, "tree run must converge: {d0} -> {d1}");
    // per-round accounting matches across wires too
    for ((ra, rc), rd) in a.metrics.records.iter().zip(&c.metrics.records).zip(&d.metrics.records)
    {
        assert_eq!(ra.uplink_bytes, rc.uplink_bytes, "round {}", ra.round);
        assert_eq!(ra.downlink_bytes, rc.downlink_bytes, "round {}", ra.round);
        assert_eq!(ra.uplink_bytes, rd.uplink_bytes, "round {} (evented)", ra.round);
        assert_eq!(ra.downlink_bytes, rd.downlink_bytes, "round {} (evented)", ra.round);
        assert_eq!(ra.participants, nodes, "round {}: FullSync over the tree", ra.round);
    }
    // relay level accounting: 4 relays, one merge each per round
    for res in [&a, &c, &d] {
        assert_eq!(res.metrics.relay_levels.len(), 1);
        let l = res.metrics.relay_levels[0];
        assert_eq!(l.level, 1);
        assert_eq!(l.relays, 4);
        assert_eq!(l.merges, 4 * rounds);
        assert!(l.ingress_bytes > 0);
        assert!(l.egress_bytes > 0);
        assert!(
            l.egress_bytes <= l.ingress_bytes,
            "lossless merge cannot grow the stream: egress {} vs ingress {}",
            l.egress_bytes,
            l.ingress_bytes
        );
        assert!(l.merge_ms >= 0.0);
    }
    // the dense reference and round-0 root egress reflect the root's
    // fanout links (4 relay children), not n worker links
    assert_eq!(a.metrics.records[0].downlink_bytes, (4 * 4 * dim) as u64);
}

/// The acceptance bound: at n=16 / fanout=4, overlapping top-k picks make
/// each subtree union collapse toward one worker's k, so measured root
/// ingress drops to ~fanout/n of star's (ε-bounded), on real counters.
#[test]
fn tree_root_ingress_drops_towards_fanout_over_n() {
    let dim = 2048;
    let nodes = 16;
    let rounds = 12;
    // Shared target + tiny gradient noise: worker top-k picks overlap
    // heavily — the regime hierarchical top-k aggregation rests on (and
    // the one the acceptance bound is stated for). Deterministic top-k
    // (not rTop-k) keeps the picks aligned across workers, and the noise
    // is kept ~50x below the bulk coordinate scale so near-threshold rank
    // churn (which decorrelates picks and inflates the unions) stays in a
    // thin band.
    let noise = 0.002;
    let cfg_star = quick_cfg(SparsifierKind::TopK, 0.9, nodes, rounds);
    let mut cfg_tree = cfg_star.clone();
    cfg_tree.set_topology("tree:fanout=4,depth=2").unwrap();
    let star = run_on(&cfg_star, dim, noise, coordinator::Transport::InProcess);
    let tree = run_on(&cfg_tree, dim, noise, coordinator::Transport::InProcess);
    let star_ingress = star.metrics.mean_root_ingress_bytes();
    let tree_ingress = tree.metrics.mean_root_ingress_bytes();
    assert!(star_ingress > 0.0 && tree_ingress > 0.0);
    let ratio = tree_ingress / star_ingress;
    // fanout/n = 0.25; ε covers residual non-overlap + per-frame headers
    assert!(
        ratio <= 0.35,
        "root ingress ratio {ratio:.3} (tree {tree_ingress:.0} B/round vs star \
         {star_ingress:.0} B/round) must approach fanout/n = 0.25"
    );
    // both converge to comparable quality (lossless relays change only
    // float association, never the support)
    let model = MockModel::new(dim, noise, 42);
    let d0 = model.distance_sq(&model.init_params());
    let ds = model.distance_sq(&star.params) / d0;
    let dt = model.distance_sq(&tree.params) / d0;
    assert!(ds < 0.3, "star converges: {ds}");
    assert!(dt < 0.3, "tree converges: {dt}");
}

/// gTop-k-style lossy relays: `--relay-budget k` caps each merged frame,
/// cutting root ingress further while still converging.
#[test]
fn relay_budget_cuts_root_ingress_and_converges() {
    let dim = 2048;
    let nodes = 8;
    let rounds = 30;
    let mut cfg = quick_cfg(SparsifierKind::TopK, 0.9, nodes, rounds);
    cfg.set_topology("tree:fanout=4,depth=2").unwrap();
    let mut cfg_budget = cfg.clone();
    cfg_budget.relay_budget = Some(dim / 10); // one worker's k
    let lossless = run_on(&cfg, dim, 0.05, coordinator::Transport::InProcess);
    let lossy = run_on(&cfg_budget, dim, 0.05, coordinator::Transport::InProcess);
    assert!(
        lossy.metrics.mean_root_ingress_bytes() <= lossless.metrics.mean_root_ingress_bytes(),
        "a relay budget can only shrink the merged frames"
    );
    let model = MockModel::new(dim, 0.05, 42);
    let d0 = model.distance_sq(&model.init_params());
    let d1 = model.distance_sq(&lossy.params);
    assert!(d1 < 0.2 * d0, "lossy-relay run must still converge: {d0} -> {d1}");
}

/// The parallel-aggregation pin (DESIGN.md §13): `--agg-threads 4`
/// (parallel frame decode, range-partitioned merge, parallel sparse step)
/// must be bit-identical to `--agg-threads 1` (the literal serial code
/// path) — params AND every per-round byte counter — on star and tree,
/// over the in-process wire and both TCP wires. The model dim exceeds
/// SELECT_CHUNK so the range-partitioned merge genuinely splits.
#[test]
fn agg_threads_bit_identical_on_star_and_tree_on_both_transports_tcp() {
    let dim = 2 * rtopk::util::chunkpool::SELECT_CHUNK + 37;
    let star = quick_cfg(SparsifierKind::RTopK, 0.99, 4, 6);
    let mut tree = quick_cfg(SparsifierKind::RTopK, 0.99, 8, 6);
    tree.set_topology("tree:fanout=4,depth=2").unwrap();
    for cfg in [&star, &tree] {
        for transport in [
            coordinator::Transport::InProcess,
            coordinator::Transport::Tcp,
            coordinator::Transport::TcpEvented,
        ] {
            // set both sides explicitly: the default may be overridden by
            // RTOPK_AGG_THREADS (the CI thread-invariance pass sets 4)
            let mut cfg_serial = cfg.clone();
            cfg_serial.agg_threads = 1;
            let mut cfg_par = cfg.clone();
            cfg_par.agg_threads = 4;
            let a = run_on(&cfg_serial, dim, 0.05, transport);
            let b = run_on(&cfg_par, dim, 0.05, transport);
            for (x, y) in a.params.iter().zip(&b.params) {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "agg-threads 1 vs 4 params must be bitwise equal \
                     (topology={:?}, {transport:?})",
                    cfg.topology
                );
            }
            assert_eq!(a.metrics.records.len(), b.metrics.records.len());
            for (ra, rb) in a.metrics.records.iter().zip(&b.metrics.records) {
                assert_eq!(ra.uplink_bytes, rb.uplink_bytes, "round {}", ra.round);
                assert_eq!(ra.uplink_coords, rb.uplink_coords, "round {}", ra.round);
                assert_eq!(ra.downlink_bytes, rb.downlink_bytes, "round {}", ra.round);
                assert_eq!(ra.participants, rb.participants, "round {}", ra.round);
            }
        }
    }
}

/// Relay fault path: a failing worker inside one subtree must error the
/// whole cluster (worker → relay → root via WorkerFailed), never hang —
/// in-process wire.
#[test]
fn subtree_worker_failure_errors_cluster_inprocess() {
    subtree_worker_failure_errors_cluster(coordinator::Transport::InProcess);
}

/// Same fault path over TCP sockets.
#[test]
fn subtree_worker_failure_errors_cluster_tcp() {
    subtree_worker_failure_errors_cluster(coordinator::Transport::Tcp);
}

/// Same fault path over the evented reactor.
#[test]
fn subtree_worker_failure_errors_cluster_tcp_evented() {
    subtree_worker_failure_errors_cluster(coordinator::Transport::TcpEvented);
}

fn subtree_worker_failure_errors_cluster(transport: coordinator::Transport) {
    let dim = 64;
    let inner = mock_worker_factory(dim, 0.05, 8);
    let factory: WorkerFactory = Arc::new(move |node| {
        anyhow::ensure!(node != 5, "node 5 boom");
        inner(node)
    });
    let mut cfg = quick_cfg(SparsifierKind::TopK, 0.9, 8, 10);
    cfg.set_topology("tree:fanout=4,depth=2").unwrap();
    let err = match coordinator::run_with(
        &cfg,
        "bad-subtree",
        vec![0.0; dim],
        factory,
        Box::new(|| Ok(None)),
        transport,
    ) {
        Err(e) => e,
        Ok(_) => panic!("a failed worker in a subtree must error the run, not hang it"),
    };
    assert!(format!("{err:#}").contains("node 5 boom"), "{err:#}");
}

/// A PANICKING worker mid-subtree (not an Err) must also unwind cleanly
/// through the relay: the worker's drop-guard reports WorkerFailed, the
/// relay's gather aborts, the relay's guard propagates the failure up and
/// Shutdown down — no hang on either wire.
#[test]
fn subtree_worker_panic_errors_cluster_tcp() {
    let dim = 64;
    let inner = mock_worker_factory(dim, 0.05, 8);
    let factory: WorkerFactory = Arc::new(move |node| {
        if node == 6 {
            panic!("node 6 panicked");
        }
        inner(node)
    });
    let mut cfg = quick_cfg(SparsifierKind::TopK, 0.9, 8, 10);
    cfg.set_topology("tree:fanout=4,depth=2").unwrap();
    for transport in [
        coordinator::Transport::InProcess,
        coordinator::Transport::Tcp,
        coordinator::Transport::TcpEvented,
    ] {
        let inner = factory.clone();
        let err = coordinator::run_with(
            &cfg,
            "panicky-subtree",
            vec![0.0; dim],
            inner,
            Box::new(|| Ok(None)),
            transport,
        );
        assert!(err.is_err(), "a panicking subtree worker must error the run ({transport:?})");
    }
}

/// Quorum at the root composes with a straggling subtree: the responsive
/// subtrees close every round, the straggler's relay never deadlocks its
/// gather, and the whole thing is deterministic across reruns AND wires.
#[test]
fn quorum_at_root_composes_with_straggling_subtree_tcp() {
    let dim = 256;
    let nodes = 8;
    let rounds = 20;
    let model = MockModel::new(dim, 0.05, 42);
    let d0 = model.distance_sq(&model.init_params());
    let mut cfg = quick_cfg(SparsifierKind::RTopK, 0.9, nodes, rounds);
    cfg.lr = LrSchedule::constant(0.2);
    cfg.set_topology("tree:fanout=4,depth=2").unwrap();
    // worker 7 (the whole of subtree 3's second leaf) delayed past the end
    // of the run: relay 3's scaled quorum (ceil(6*2/8) = 2) can never close
    // in time, so the root must close every round on subtrees 0..2 alone.
    cfg.set_gather("quorum:m=6,timeout_ms=2").unwrap();
    cfg.straggler = Some(StragglerSim { worker: 7, delay_ms: 1000 });
    let a = run_on(&cfg, dim, 0.05, coordinator::Transport::InProcess);
    let b = run_on(&cfg, dim, 0.05, coordinator::Transport::InProcess);
    let c = run_on(&cfg, dim, 0.05, coordinator::Transport::Tcp);
    assert_eq!(a.params, b.params, "straggling-subtree quorum must be reproducible");
    assert_eq!(a.params, c.params, "transports must agree");
    let d1 = model.distance_sq(&a.params);
    assert!(d1 < 0.3 * d0, "quorum tree run must converge: {d0} -> {d1}");
    for res in [&a, &b, &c] {
        for r in &res.metrics.records {
            assert_eq!(
                r.participants, 6,
                "round {}: 3 subtrees × 2 leaves close the quorum",
                r.round
            );
        }
        // per-direct-child participation: subtrees 0..2 every round, the
        // straggling subtree never in time
        assert_eq!(res.metrics.worker_participation, vec![rounds, rounds, rounds, 0]);
        // participants are in LEAF-WORKER units: 6 of 8 leaves per round
        let rate = res.metrics.participation_rate(nodes);
        assert!((rate - 0.75).abs() < 1e-12, "leaf participation rate {rate}");
    }
}

/// Delta downlink over a tree: the encode-once frame is shared per hop
/// (root pays ONE frame regardless of subtree sizes) and the run matches
/// the star trajectory's convergence.
#[test]
fn tree_delta_downlink_shares_one_frame_per_hop() {
    let dim = 512;
    let nodes = 8;
    let mut cfg = quick_cfg(SparsifierKind::TopK, 0.9, nodes, 25);
    cfg.set_downlink("delta").unwrap();
    cfg.set_topology("tree:fanout=4,depth=2").unwrap();
    let model = MockModel::new(dim, 0.05, 42);
    let d0 = model.distance_sq(&model.init_params());
    let res = run_on(&cfg, dim, 0.05, coordinator::Transport::InProcess);
    let d1 = model.distance_sq(&res.params);
    assert!(d1 < 0.3 * d0, "delta downlink on a tree must converge: {d0} -> {d1}");
    // round 0 dense fallback: one dense unicast per DIRECT child (4 relays)
    assert_eq!(res.metrics.records[0].downlink_bytes, (4 * 4 * dim) as u64);
    // steady state: one shared frame at the root, far below its own round-0
    let last = res.metrics.records.last().unwrap();
    assert!(last.downlink_bytes > 0);
    assert!(
        last.downlink_bytes < (4 * dim) as u64,
        "steady-state root egress {} should be below one dense frame {}",
        last.downlink_bytes,
        4 * dim
    );
}

/// Partitioned layout × tree: segmented frames survive the relay-side
/// re-encode and the per-segment accounting at the root stays exact.
#[test]
fn tree_with_partitioned_layout_keeps_segment_accounting_exact() {
    let dim = 512;
    let nodes = 8;
    let mut cfg = quick_cfg(SparsifierKind::RTopK, 0.9, nodes, 15);
    cfg.set_layout("even:n=4").unwrap();
    cfg.set_topology("tree:fanout=4,depth=2").unwrap();
    let model = MockModel::new(dim, 0.05, 42);
    let d0 = model.distance_sq(&model.init_params());
    let res = run_on(&cfg, dim, 0.05, coordinator::Transport::InProcess);
    let d1 = model.distance_sq(&res.params);
    assert!(d1 < 0.3 * d0, "partitioned tree run must converge: {d0} -> {d1}");
    assert_eq!(res.metrics.segment_names.len(), 4);
    for r in &res.metrics.records {
        assert_eq!(r.seg_bytes.len(), 4);
        assert_eq!(
            r.seg_bytes.iter().sum::<u64>() + r.seg_overhead_bytes,
            r.uplink_bytes,
            "round {}: root-ingress per-segment bytes must sum to the measured total",
            r.round
        );
    }
}
