//! Integration tests over the XLA (PJRT) runtime and the AOT artifacts.
//!
//! These need `make artifacts` to have produced `artifacts/` (the tiny
//! presets suffice); they skip — loudly — when artifacts are absent so
//! `cargo test` still works in a fresh checkout.

use std::path::PathBuf;

use rtopk::runtime::{Batch, Manifest, ModelRuntime, XlaModel};
use rtopk::runtime::xla_runtime::XlaSparsePipeline;
use rtopk::sparsify::select::MagnitudeHistogram;
use rtopk::util::rng::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

fn lm_batch(model: &XlaModel, seed: u64) -> Batch {
    let meta = &model.entry.meta;
    let batch = meta.get("batch").unwrap().as_usize().unwrap();
    let seq = meta.get("seq").unwrap().as_usize().unwrap();
    let vocab = meta.get("vocab").unwrap().as_usize().unwrap();
    let mut rng = Rng::new(seed);
    let tokens: Vec<i32> = (0..batch * (seq + 1))
        .map(|_| rng.index(vocab) as i32)
        .collect();
    Batch::Tokens { tokens, batch, seq_plus_1: seq + 1 }
}

#[test]
fn lm_tiny_initial_loss_near_uniform() {
    let Some(dir) = artifacts_dir() else { return };
    let mut model = XlaModel::load(&dir, "lm_tiny").unwrap();
    let params = model.init_params();
    let vocab = model.entry.meta.get("vocab").unwrap().as_usize().unwrap();
    let mut grads = Vec::new();
    let loss = model
        .train_step(&params, &lm_batch(&model, 0), &mut grads)
        .unwrap();
    let expect = (vocab as f32).ln();
    assert!(
        (loss - expect).abs() < 0.5,
        "initial loss {loss} vs ln(vocab) {expect}"
    );
    assert_eq!(grads.len(), model.dim());
    assert!(grads.iter().all(|g| g.is_finite()));
}

#[test]
fn lm_tiny_descent_reduces_loss() {
    let Some(dir) = artifacts_dir() else { return };
    let mut model = XlaModel::load(&dir, "lm_tiny").unwrap();
    let mut params = model.init_params();
    let batch = lm_batch(&model, 1);
    let mut grads = Vec::new();
    let loss0 = model.train_step(&params, &batch, &mut grads).unwrap();
    let mut loss = loss0;
    for _ in 0..5 {
        for (w, &g) in params.iter_mut().zip(&grads) {
            *w -= 0.5 * g;
        }
        loss = model.train_step(&params, &batch, &mut grads).unwrap();
    }
    assert!(loss < loss0, "one-batch SGD must overfit: {loss0} -> {loss}");
}

#[test]
fn lm_tiny_eval_matches_train_loss() {
    let Some(dir) = artifacts_dir() else { return };
    let mut model = XlaModel::load(&dir, "lm_tiny").unwrap();
    let params = model.init_params();
    let batch = lm_batch(&model, 2);
    let mut grads = Vec::new();
    let loss = model.train_step(&params, &batch, &mut grads).unwrap();
    let (nll_sum, count) = model.eval_step(&params, &batch).unwrap();
    assert!(
        ((nll_sum / count) - loss as f64).abs() < 1e-4,
        "eval {} vs train {loss}",
        nll_sum / count
    );
}

#[test]
fn cnn_tiny_loads_and_evaluates() {
    let Some(dir) = artifacts_dir() else { return };
    let mut model = XlaModel::load(&dir, "cnn_tiny").unwrap();
    let params = model.init_params();
    let meta = &model.entry.meta;
    let batch = meta.get("batch").unwrap().as_usize().unwrap();
    let image = meta.get("image").unwrap().as_usize().unwrap();
    let classes = meta.get("classes").unwrap().as_usize().unwrap();
    let mut rng = Rng::new(3);
    let pixels = rng.normal_vec(batch * image * image * 3, 0.0, 1.0);
    let labels: Vec<i32> = (0..batch).map(|_| rng.index(classes) as i32).collect();
    let b = Batch::Images { pixels, labels };
    let mut grads = Vec::new();
    let loss = model.train_step(&params, &b, &mut grads).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    let (correct, count) = model.eval_step(&params, &b).unwrap();
    assert!(correct >= 0.0 && correct <= count);
    assert_eq!(count as usize, batch);
}

#[test]
fn batch_shape_mismatch_rejected() {
    let Some(dir) = artifacts_dir() else { return };
    let mut model = XlaModel::load(&dir, "lm_tiny").unwrap();
    let params = model.init_params();
    let bad = Batch::Tokens { tokens: vec![0; 10], batch: 2, seq_plus_1: 5 };
    let mut grads = Vec::new();
    assert!(model.train_step(&params, &bad, &mut grads).is_err());
    let wrong_family = Batch::Images { pixels: vec![0.0; 12], labels: vec![0] };
    assert!(model.train_step(&params, &wrong_family, &mut grads).is_err());
}

#[test]
fn sparse_pipeline_matches_pure_rust() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let Some(entry) = manifest.sparse_pipelines.first() else {
        eprintln!("SKIP: no sparse pipeline in manifest");
        return;
    };
    let pipe = XlaSparsePipeline::load(&manifest, entry.dim).unwrap();
    let d = pipe.dim;
    let mut rng = Rng::new(4);
    let g = rng.normal_vec(d, 0.0, 1.5);
    let m = rng.normal_vec(d, 0.0, 0.2);
    // host side computes acc = g + m for the reference paths
    let acc: Vec<f32> = g.iter().zip(&m).map(|(&a, &b)| a + b).collect();
    let mx_ref = acc.iter().fold(0f32, |a, &v| a.max(v.abs()));
    let log_hi = mx_ref.max(1e-38).ln();
    let log_lo = log_hi - MagnitudeHistogram::DEFAULT_SPAN;
    let thresh = 1.0f32;

    let (hist, out, m_new, nnz, mx) = pipe.run(&g, &m, log_lo, log_hi, thresh).unwrap();

    // maxabs agrees
    assert!((mx - mx_ref).abs() < 1e-5 * mx_ref, "{mx} vs {mx_ref}");

    // histogram agrees with the Rust implementation up to f32 bin-edge
    // rounding (identical formula, different evaluation order)
    let mut rust_hist = MagnitudeHistogram {
        counts: vec![0; pipe.nbins],
        log_lo,
        log_hi,
    };
    rust_hist.accumulate(&acc);
    let total_xla: i64 = hist.iter().map(|&c| c as i64).sum();
    assert_eq!(total_xla as usize, d, "histogram must count all elements");
    let l1_diff: u64 = hist
        .iter()
        .zip(&rust_hist.counts)
        .map(|(&a, &b)| (a as i64 - b as i64).unsigned_abs())
        .sum();
    assert!(
        l1_diff <= (d / 500 + 2) as u64,
        "histograms diverge: L1 diff {l1_diff} of {d}"
    );

    // threshold apply agrees exactly with the definition
    let mut expect_nnz = 0;
    for j in 0..d {
        let keep = acc[j].abs() >= thresh;
        if keep {
            expect_nnz += 1;
            assert!((out[j] - acc[j]).abs() < 1e-6, "out[{j}]");
            assert_eq!(m_new[j], 0.0, "m_new[{j}]");
        } else {
            assert_eq!(out[j], 0.0, "out[{j}]");
            assert!((m_new[j] - acc[j]).abs() < 1e-6, "m_new[{j}]");
        }
    }
    assert_eq!(nnz as usize, expect_nnz);
}

#[test]
fn manifest_hashes_match_files() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    for m in &manifest.models {
        for prog in [&m.train, &m.eval] {
            let text = std::fs::read_to_string(dir.join(&prog.file)).unwrap();
            assert!(text.starts_with("HloModule"), "{} is not HLO text", prog.file);
        }
        // flat-param contract: input 0 and grad output are both f32[dim]
        assert_eq!(m.train.inputs[0].shape, vec![m.dim]);
        assert_eq!(m.train.outputs[1].shape, vec![m.dim]);
    }
}
