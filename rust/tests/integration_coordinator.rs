//! Integration tests: full Algorithm-1 runs over the in-process cluster,
//! on the MockModel (fast, exact) and the pure-Rust CNN (realistic).

use std::sync::Arc;

use rtopk::coordinator::{
    self, OptimKind, RoundMode, StragglerSim, TrainConfig, WorkerFactory, WorkerSetup,
};
use rtopk::data::images::{self, ImageDatasetConfig};
use rtopk::experiments::tasks::ImageTask;
use rtopk::optim::LrSchedule;
use rtopk::runtime::{Batch, MockModel, ModelRuntime, RustNetConfig};
use rtopk::sparsify::SparsifierKind;

fn mock_factory(dim: usize, noise: f32) -> WorkerFactory {
    coordinator::mock_worker_factory(dim, noise, 8)
}

fn quick_cfg(method: SparsifierKind, compression: f64, rounds: u64) -> TrainConfig {
    let mut cfg = TrainConfig::image_default(4, method, compression);
    cfg.rounds = rounds;
    cfg.warmup_epochs = 0.0;
    cfg.optim = OptimKind::Sgd { clip: None };
    cfg.lr = LrSchedule::constant(0.3);
    cfg.eval_every = rounds;
    cfg
}

fn final_distance(method: SparsifierKind, compression: f64, rounds: u64) -> f64 {
    let dim = 512;
    let cfg = quick_cfg(method, compression, rounds);
    let model = MockModel::new(dim, 0.05, 42);
    let res = coordinator::run(
        &cfg,
        "itest",
        model.init_params(),
        mock_factory(dim, 0.05),
        Box::new(|| Ok(None)),
    )
    .unwrap();
    model.distance_sq(&res.params)
}

#[test]
fn all_methods_make_progress() {
    let dim = 512;
    let model = MockModel::new(dim, 0.05, 42);
    let d0 = model.distance_sq(&model.init_params());
    for method in [
        SparsifierKind::Baseline,
        SparsifierKind::TopK,
        SparsifierKind::RandomK,
        SparsifierKind::RTopK,
        SparsifierKind::Threshold,
    ] {
        let d1 = final_distance(method, 0.9, 80);
        assert!(d1 < d0, "{method:?}: {d0} -> {d1}");
    }
}

#[test]
fn rtopk_beats_randomk_at_same_budget() {
    // The paper's core empirical claim at the mock scale: at matched k,
    // rTop-k converges at least as fast as random-k.
    let d_rtop = final_distance(SparsifierKind::RTopK, 0.98, 60);
    let d_rand = final_distance(SparsifierKind::RandomK, 0.98, 60);
    assert!(
        d_rtop < d_rand,
        "rTop-k ({d_rtop}) should beat random-k ({d_rand})"
    );
}

#[test]
fn atopk_chain_tracks_exact_rtopk_convergence() {
    // The approximate chain (atopk:r=auto>random, multi-threaded select)
    // is exact in the Definition-1 sense — only tie-breaks and the RNG
    // stream differ from rtopk — so a full training run must land in the
    // same convergence regime as the exact pipeline, and error feedback
    // absorbs whichever tie-set representative each round picks.
    let dim = 512;
    let exact_cfg = quick_cfg(SparsifierKind::RTopK, 0.98, 60);
    let mut approx_cfg = exact_cfg.clone();
    approx_cfg.set_pipeline("atopk:r=auto,sample=2048>random").unwrap();
    approx_cfg.select_threads = 4;
    let model = MockModel::new(dim, 0.05, 42);
    let run = |cfg: &TrainConfig, tag: &str| {
        coordinator::run(
            cfg,
            tag,
            model.init_params(),
            mock_factory(dim, 0.05),
            Box::new(|| Ok(None)),
        )
        .unwrap()
    };
    let d0 = model.distance_sq(&model.init_params());
    let d_exact = model.distance_sq(&run(&exact_cfg, "rtopk-exact").params);
    let d_approx = model.distance_sq(&run(&approx_cfg, "rtopk-atopk").params);
    assert!(d_approx < 0.5 * d0, "atopk chain failed to converge: {d0} -> {d_approx}");
    assert!(
        d_approx < 3.0 * d_exact + 1e-3,
        "atopk chain ({d_approx}) drifted far from exact rtopk ({d_exact})"
    );
}

#[test]
fn error_feedback_improves_topk() {
    let dim = 512;
    let mut with = quick_cfg(SparsifierKind::TopK, 0.99, 80);
    let mut without = with.clone();
    without.error_feedback = false;
    // moderate lr so the biased run doesn't diverge
    with.lr = LrSchedule::constant(0.2);
    without.lr = LrSchedule::constant(0.2);
    let model = MockModel::new(dim, 0.05, 42);
    let run = |cfg: &TrainConfig| {
        coordinator::run(
            cfg,
            "ef-ablation",
            model.init_params(),
            mock_factory(dim, 0.05),
            Box::new(|| Ok(None)),
        )
        .unwrap()
    };
    let d_with = model.distance_sq(&run(&with).params);
    let d_without = model.distance_sq(&run(&without).params);
    assert!(
        d_with < d_without,
        "error feedback should help top-k: with={d_with} without={d_without}"
    );
}

#[test]
fn federated_mode_runs_and_converges() {
    let dim = 256;
    let mut cfg = quick_cfg(SparsifierKind::RTopK, 0.9, 15);
    cfg.mode = RoundMode::Federated;
    cfg.lr = LrSchedule::constant(0.1);
    let model = MockModel::new(dim, 0.05, 42);
    let res = coordinator::run(
        &cfg,
        "fed",
        model.init_params(),
        mock_factory(dim, 0.05),
        Box::new(|| Ok(None)),
    )
    .unwrap();
    let d0 = model.distance_sq(&model.init_params());
    let d1 = model.distance_sq(&res.params);
    assert!(d1 < 0.2 * d0, "{d0} -> {d1}");
    // each federated round processed one local epoch (8 batches)
    assert_eq!(res.metrics.records.len(), 15);
}

#[test]
fn warmup_rounds_send_more_bytes_than_steady_state() {
    let dim = 2048;
    let mut cfg = quick_cfg(SparsifierKind::TopK, 0.99, 40);
    cfg.warmup_epochs = 2.0; // 16 rounds of ramp at 8 batches/epoch
    let res = coordinator::run(
        &cfg,
        "warmup",
        vec![0.0; dim],
        mock_factory(dim, 0.05),
        Box::new(|| Ok(None)),
    )
    .unwrap();
    let first = res.metrics.records.first().unwrap().uplink_bytes;
    let last = res.metrics.records.last().unwrap().uplink_bytes;
    assert!(
        first > 10 * last,
        "round 0 ({first} B) should dwarf steady state ({last} B)"
    );
    // k follows the schedule
    assert!(res.metrics.records[0].k_used > res.metrics.records[39].k_used);
}

#[test]
fn cnn_cluster_learns_above_chance() {
    // 3 nodes, tiny synthetic image task, a handful of epochs: accuracy
    // must clear chance by a wide margin.
    let data_cfg = ImageDatasetConfig {
        classes: 4,
        image: 16,
        train_per_class: 60,
        test_per_class: 25,
        noise: 0.3,
        max_shift: 2,
        seed: 99,
    };
    let net = RustNetConfig { classes: 4, channels: vec![8, 16], hidden: 32, image: 16 };
    let task = ImageTask::new(&data_cfg, net, 3, 16);
    let mut cfg = TrainConfig::image_default(3, SparsifierKind::RTopK, 0.9);
    cfg.rounds = 50;
    cfg.warmup_epochs = 1.0;
    cfg.eval_every = 25;
    cfg.lr = LrSchedule::constant(0.05);
    let ev = task.evaluator().unwrap();
    let res = coordinator::run(
        &cfg,
        "cnn",
        task.init_params(),
        task.worker_factory(),
        Box::new(move || Ok(Some(ev))),
    )
    .unwrap();
    let acc = res.metrics.best_eval().unwrap();
    assert!(acc > 0.5, "accuracy {acc} vs chance 0.25");
}

#[test]
fn identical_seeds_reproduce_bitwise() {
    let dim = 128;
    let cfg = quick_cfg(SparsifierKind::RTopK, 0.95, 20);
    let run = || {
        coordinator::run(
            &cfg,
            "repro",
            vec![0.0; dim],
            mock_factory(dim, 0.1),
            Box::new(|| Ok(None)),
        )
        .unwrap()
        .params
    };
    assert_eq!(run(), run(), "same config+seed must be bitwise identical");
}

#[test]
fn heterogeneous_shards_still_converge() {
    // Workers with different targets (heterogeneity): converge to average.
    let dim = 64;
    let factory: WorkerFactory = Arc::new(move |node| {
        let mut counter = node as u64 * 7_000;
        // Different seed per node -> different target
        Ok(WorkerSetup {
            runtime: Box::new(MockModel::new(dim, 0.02, 100 + node as u64)),
            next_batch: Box::new(move |_rng| {
                counter += 1;
                Batch::Seed(counter)
            }),
            batches_per_epoch: 4,
        })
    });
    let mut cfg = quick_cfg(SparsifierKind::RTopK, 0.8, 160);
    // Heterogeneous targets mean per-worker gradients do NOT vanish at the
    // population optimum; a constant lr oscillates there. Decay it (the
    // paper's Theorem 3 likewise requires a piecewise schedule).
    cfg.lr = LrSchedule::steps(0.3, &[10, 20, 30], 0.3);
    let res = coordinator::run(&cfg, "hetero", vec![0.0; dim], factory, Box::new(|| Ok(None)))
        .unwrap();
    // population optimum = average of the three targets
    let targets: Vec<Vec<f32>> = (0..4).map(|i| MockModel::new(dim, 0.0, 100 + i).target).collect();
    let mut avg = vec![0.0f32; dim];
    for t in &targets {
        for (a, &v) in avg.iter_mut().zip(t) {
            *a += v / targets.len() as f32;
        }
    }
    let dist: f64 = res
        .params
        .iter()
        .zip(&avg)
        .map(|(&w, &t)| ((w - t) as f64).powi(2))
        .sum();
    let norm: f64 = avg.iter().map(|&t| (t as f64).powi(2)).sum();
    assert!(dist < 0.05 * norm, "dist {dist} vs ||avg||^2 {norm}");
}

#[test]
fn image_dataset_shared_across_factories() {
    // ImageTask should expose deterministic shards covering the train set.
    let data_cfg = ImageDatasetConfig {
        classes: 3,
        image: 8,
        train_per_class: 12,
        test_per_class: 6,
        noise: 0.2,
        max_shift: 1,
        seed: 5,
    };
    let (train, _) = images::generate(&data_cfg);
    let task = ImageTask::new(&data_cfg, RustNetConfig::tiny(), 3, 4);
    assert_eq!(task.shards.total(), train.len());
}

#[test]
fn tcp_transport_matches_inprocess_bitwise() {
    // Same config + seed over loopback TCP must produce the exact same
    // trained parameters as the in-process channels (the transport is
    // pure plumbing; framing must not perturb payloads) — in BOTH
    // downlink modes: dense params and the compressed sparse delta.
    let dim = 96;
    for downlink in ["dense", "delta", "baseline|bf16|delta"] {
        let mut cfg = quick_cfg(SparsifierKind::RTopK, 0.9, 12);
        cfg.set_downlink(downlink).unwrap();
        let run_on = |t: coordinator::Transport| {
            coordinator::run_with(
                &cfg,
                "transport-eq",
                vec![0.0; dim],
                mock_factory(dim, 0.1),
                Box::new(|| Ok(None)),
                t,
            )
            .unwrap()
        };
        let a = run_on(coordinator::Transport::InProcess);
        let b = run_on(coordinator::Transport::Tcp);
        assert_eq!(
            a.params, b.params,
            "transports must be payload-equivalent (downlink={downlink})"
        );
        // entry counts match exactly; byte counts also match because the
        // counter records codec payload bytes in both cases — for the
        // downlink too (dense frames per link, delta frames once).
        let coords_a: u64 = a.metrics.records.iter().map(|r| r.uplink_coords).sum();
        let coords_b: u64 = b.metrics.records.iter().map(|r| r.uplink_coords).sum();
        assert_eq!(coords_a, coords_b, "downlink={downlink}");
        let up_a: u64 = a.metrics.records.iter().map(|r| r.uplink_bytes).sum();
        let up_b: u64 = b.metrics.records.iter().map(|r| r.uplink_bytes).sum();
        assert_eq!(up_a, up_b, "downlink={downlink}");
        let down_a: u64 = a.metrics.records.iter().map(|r| r.downlink_bytes).sum();
        let down_b: u64 = b.metrics.records.iter().map(|r| r.downlink_bytes).sum();
        assert_eq!(down_a, down_b, "downlink={downlink}");
    }
}

#[test]
fn quorum_straggler_converges_deterministically_on_both_transports() {
    // One worker delayed past the END of the whole run (1s delay vs a
    // ~100ms run): every round must close with the 3 fast workers, the
    // participation accounting must record the misses, and — because the
    // participant set is then identical every round by construction, with
    // a huge timing margin against CI scheduler stalls — the trajectory
    // must be bitwise reproducible across reruns AND transports. (The
    // drop-and-count path for stale updates that DO land mid-run is
    // covered deterministically by the gather unit tests.)
    let dim = 256;
    let model = MockModel::new(dim, 0.05, 42);
    let d0 = model.distance_sq(&model.init_params());
    let mut cfg = quick_cfg(SparsifierKind::RTopK, 0.9, 30);
    cfg.lr = LrSchedule::constant(0.2);
    cfg.set_gather("quorum:m=3,timeout_ms=2").unwrap();
    cfg.straggler = Some(StragglerSim { worker: 3, delay_ms: 1000 });
    let run_on = |t: coordinator::Transport| {
        coordinator::run_with(
            &cfg,
            "quorum-straggler",
            model.init_params(),
            mock_factory(dim, 0.05),
            Box::new(|| Ok(None)),
            t,
        )
        .unwrap()
    };
    let a = run_on(coordinator::Transport::InProcess);
    let b = run_on(coordinator::Transport::InProcess);
    let c = run_on(coordinator::Transport::Tcp);
    // deterministic across reruns and across wires
    assert_eq!(a.params, b.params, "quorum straggler run must be reproducible");
    assert_eq!(a.params, c.params, "transports must agree under quorum");
    // converges on the 3 fast workers' signal
    let d1 = model.distance_sq(&a.params);
    assert!(d1 < 0.3 * d0, "quorum run must converge: {d0} -> {d1}");
    for res in [&a, &b, &c] {
        for r in &res.metrics.records {
            assert_eq!(r.participants, 3, "round {}: straggler must miss", r.round);
        }
        // the 3 fast workers participated every round, the straggler never
        assert_eq!(res.metrics.worker_participation, vec![30, 30, 30, 0]);
        assert!(res.metrics.participation_rate(4) < 1.0);
    }
}

#[test]
fn quorum_with_delta_downlink_keeps_straggler_in_sync() {
    // The straggler applies every queued delta in order while catching up;
    // when its update finally lands fresh (no quorum pressure at the end is
    // not guaranteed, so assert convergence + determinism only).
    let dim = 128;
    let model = MockModel::new(dim, 0.05, 42);
    let d0 = model.distance_sq(&model.init_params());
    let mut cfg = quick_cfg(SparsifierKind::RTopK, 0.9, 25);
    cfg.lr = LrSchedule::constant(0.2);
    cfg.set_gather("quorum:m=3,timeout_ms=2").unwrap();
    cfg.set_downlink("delta").unwrap();
    cfg.straggler = Some(StragglerSim { worker: 3, delay_ms: 1000 });
    let run_once = || {
        coordinator::run(
            &cfg,
            "quorum-delta",
            model.init_params(),
            mock_factory(dim, 0.05),
            Box::new(|| Ok(None)),
        )
        .unwrap()
    };
    let a = run_once();
    let b = run_once();
    assert_eq!(a.params, b.params);
    let d1 = model.distance_sq(&a.params);
    assert!(d1 < 0.3 * d0, "{d0} -> {d1}");
    // delta downlink still pays one shared frame per steady-state round
    assert!(a.metrics.records.last().unwrap().downlink_bytes > 0);
}

#[test]
fn layout_flat_bit_identical_to_single_segment_partition() {
    // The flat-layout bit-identity invariant, end to end and across the
    // two code paths: the default `--layout flat` (the pre-partitioning
    // GradientCompressor path) and `--layout even:n=1` (the partitioned
    // machinery with one segment) must produce identical parameter
    // trajectories AND identical measured wire traffic, per round.
    let dim = 256;
    let cfg_flat = quick_cfg(SparsifierKind::RTopK, 0.95, 20);
    let mut cfg_part = quick_cfg(SparsifierKind::RTopK, 0.95, 20);
    cfg_part.set_layout("even:n=1").unwrap();
    let run = |cfg: &TrainConfig| {
        coordinator::run(
            cfg,
            "layout-eq",
            vec![0.0; dim],
            mock_factory(dim, 0.1),
            Box::new(|| Ok(None)),
        )
        .unwrap()
    };
    let a = run(&cfg_flat);
    let b = run(&cfg_part);
    for (x, y) in a.params.iter().zip(&b.params) {
        assert_eq!(x.to_bits(), y.to_bits(), "flat vs even:n=1 params must be bitwise equal");
    }
    for (ra, rb) in a.metrics.records.iter().zip(&b.metrics.records) {
        assert_eq!(ra.uplink_bytes, rb.uplink_bytes, "round {}", ra.round);
        assert_eq!(ra.uplink_coords, rb.uplink_coords, "round {}", ra.round);
        assert_eq!(ra.downlink_bytes, rb.downlink_bytes, "round {}", ra.round);
        // single-segment frames are flat frames: zero partition overhead
        assert_eq!(rb.seg_overhead_bytes, 0, "round {}", ra.round);
        assert_eq!(rb.seg_bytes.iter().sum::<u64>(), rb.uplink_bytes);
    }
    assert!(a.metrics.segment_names.is_empty(), "flat run reports no segments");
    assert_eq!(b.metrics.segment_names.len(), 1);
}

#[test]
fn partitioned_tcp_matches_inprocess_bitwise_and_accounts_exactly() {
    // `--layout even:n=4` with a bf16/delta wire: identical params and
    // byte counters across transports, per-segment bytes + frame overhead
    // summing exactly to the measured uplink total every round, and
    // proportional budgets summing exactly to the flat k (counted on the
    // wire as decoded coordinates).
    let dim = 512;
    let mut cfg = quick_cfg(SparsifierKind::RTopK, 0.9, 15);
    cfg.set_pipeline("rtopk|bf16|delta").unwrap();
    cfg.set_layout("even:n=4").unwrap();
    let mut cfg_flat = cfg.clone();
    cfg_flat.set_layout("flat").unwrap();
    let model = MockModel::new(dim, 0.05, 42);
    let run_on = |cfg: &TrainConfig, t: coordinator::Transport| {
        coordinator::run_with(
            cfg,
            "part-transport-eq",
            model.init_params(),
            mock_factory(dim, 0.05),
            Box::new(|| Ok(None)),
            t,
        )
        .unwrap()
    };
    let a = run_on(&cfg, coordinator::Transport::InProcess);
    let b = run_on(&cfg, coordinator::Transport::Tcp);
    assert_eq!(a.params, b.params, "transports must agree under a partitioned layout");
    for (ra, rb) in a.metrics.records.iter().zip(&b.metrics.records) {
        assert_eq!(ra.uplink_bytes, rb.uplink_bytes, "round {}", ra.round);
        assert_eq!(ra.uplink_coords, rb.uplink_coords, "round {}", ra.round);
        assert_eq!(ra.seg_bytes, rb.seg_bytes, "round {}", ra.round);
        assert_eq!(ra.seg_overhead_bytes, rb.seg_overhead_bytes, "round {}", ra.round);
    }
    // the run converges (acceptance: full in-process + TCP run on the mock)
    let d0 = model.distance_sq(&model.init_params());
    let d1 = model.distance_sq(&a.params);
    assert!(d1 < 0.3 * d0, "partitioned run must converge: {d0} -> {d1}");
    // exact per-segment accounting under the FullSync gather
    assert_eq!(a.metrics.segment_names.len(), 4);
    for r in &a.metrics.records {
        assert_eq!(r.seg_bytes.len(), 4);
        assert_eq!(
            r.seg_bytes.iter().sum::<u64>() + r.seg_overhead_bytes,
            r.uplink_bytes,
            "round {}: per-segment bytes must sum to the measured total",
            r.round
        );
        assert!(r.seg_overhead_bytes > 0, "4-segment frames carry table overhead");
    }
    // proportional budgets sum exactly to the flat k: the coordinate count
    // on the wire matches the flat run's, round for round
    let flat = run_on(&cfg_flat, coordinator::Transport::InProcess);
    for (rp, rf) in a.metrics.records.iter().zip(&flat.metrics.records) {
        assert_eq!(
            rp.uplink_coords, rf.uplink_coords,
            "round {}: partitioned coords must equal flat k (no rounding drift)",
            rp.round
        );
        assert_eq!(rp.uplink_coords, (rp.participants * rp.k_used) as u64);
    }
}

#[test]
fn adaptive_budget_full_run_converges_and_stays_sum_exact() {
    // The 2210.13532-style adaptive reallocation end to end: per-round
    // budgets keep summing to k while following observed mass.
    let dim = 512;
    let mut cfg = quick_cfg(SparsifierKind::RTopK, 0.9, 40);
    cfg.set_layout("even:n=4").unwrap();
    cfg.set_budget("adaptive").unwrap();
    let model = MockModel::new(dim, 0.05, 42);
    let res = coordinator::run(
        &cfg,
        "adaptive-budget",
        model.init_params(),
        mock_factory(dim, 0.05),
        Box::new(|| Ok(None)),
    )
    .unwrap();
    let d0 = model.distance_sq(&model.init_params());
    let d1 = model.distance_sq(&res.params);
    assert!(d1 < 0.3 * d0, "{d0} -> {d1}");
    for r in &res.metrics.records {
        assert_eq!(r.uplink_coords, (r.participants * r.k_used) as u64, "round {}", r.round);
        assert_eq!(
            r.seg_bytes.iter().sum::<u64>() + r.seg_overhead_bytes,
            r.uplink_bytes
        );
    }
    // reproducible: adaptive state is per-worker-deterministic
    let res2 = coordinator::run(
        &cfg,
        "adaptive-budget",
        model.init_params(),
        mock_factory(dim, 0.05),
        Box::new(|| Ok(None)),
    )
    .unwrap();
    assert_eq!(res.params, res2.params);
}

#[test]
fn layout_that_cannot_fit_model_fails_fast() {
    // more segments than coordinates: the run must error out cleanly
    // (worker factory + engine both resolve the layout before round 0)
    let dim = 8;
    let mut cfg = quick_cfg(SparsifierKind::TopK, 0.5, 5);
    cfg.set_layout("even:n=16").unwrap();
    let err = coordinator::run(
        &cfg,
        "bad-layout",
        vec![0.0; dim],
        mock_factory(dim, 0.05),
        Box::new(|| Ok(None)),
    );
    assert!(err.is_err(), "16 segments over dim 8 must fail, not hang");
}

#[test]
fn dense_downlink_identical_to_delta_off() {
    // `--downlink dense` IS the legacy path: the config flag must not
    // perturb the trajectory in any way.
    let dim = 128;
    let cfg_a = quick_cfg(SparsifierKind::RTopK, 0.95, 15);
    let mut cfg_b = quick_cfg(SparsifierKind::RTopK, 0.95, 15);
    cfg_b.set_downlink("dense").unwrap();
    let run = |cfg: &coordinator::TrainConfig| {
        coordinator::run(
            cfg,
            "dense-eq",
            vec![0.0; dim],
            mock_factory(dim, 0.1),
            Box::new(|| Ok(None)),
        )
        .unwrap()
        .params
    };
    assert_eq!(run(&cfg_a), run(&cfg_b));
}

#[test]
fn delta_downlink_meets_quarter_budget_at_table1_settings() {
    // The acceptance bar: with the delta pipeline on, steady-state
    // downlink bytes/round — measured on the transport counters, not
    // computed — stay below 25% of the dense 4*d*n broadcast, under
    // table1's optimizer settings (momentum 0.9, whose velocity densifies
    // the param delta over time: the worst case for this path).
    let dim = 4096;
    let nodes = 5;
    let mut cfg = TrainConfig::image_default(nodes, SparsifierKind::RTopK, 0.99);
    cfg.rounds = 30;
    cfg.warmup_epochs = 0.5;
    cfg.eval_every = 30;
    cfg.set_downlink("delta").unwrap();
    let res = coordinator::run(
        &cfg,
        "table1-quick-downlink",
        vec![0.0; dim],
        mock_factory(dim, 0.05),
        Box::new(|| Ok(None)),
    )
    .unwrap();
    let recs = &res.metrics.records;
    assert_eq!(recs.len(), 30);
    // round 0 is the dense fallback at full n * 4d cost
    let dense_per_round = (nodes * 4 * dim) as u64;
    assert_eq!(recs[0].downlink_bytes, dense_per_round);
    // steady state (last 10 rounds): every round under 25% of dense
    for r in &recs[20..] {
        assert!(
            r.downlink_bytes > 0,
            "round {}: downlink must be measured, not assumed",
            r.round
        );
        assert!(
            4 * r.downlink_bytes < dense_per_round,
            "round {}: downlink {} >= 25% of dense {}",
            r.round,
            r.downlink_bytes,
            dense_per_round
        );
    }
    // and the run-level measured ratio agrees
    assert!(res.metrics.downlink_compression_ratio(20) > 0.75);
}
