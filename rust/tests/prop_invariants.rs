//! Property-based invariant tests over the coordinator's building blocks.
//!
//! Uses the crate's own property-test driver (`util::proptest`) since the
//! offline image vendors no proptest crate. Each property runs over many
//! seeded random cases including adversarial value distributions (ties,
//! zeros, huge/tiny magnitudes — see `gen_vector`).

use rtopk::compress::codec::{self, value_roundtrip, CodecConfig, IndexFormat, ValueFormat};
use rtopk::compress::aggregate::{
    merge_scaled_into, merge_scaled_into_pooled, merge_tree_scaled_into,
    merge_tree_scaled_into_pooled, MergeScratch, TreeMergeScratch,
};
use rtopk::coordinator::{CohortSampler, FederationConfig, SamplerKind};
use rtopk::data::PopulationSharder;
use rtopk::compress::{
    BudgetPolicy, GradientCompressor, PartitionedCompressor, PipelineSpec, SegmentLayout, Select,
    SelectScratch,
};
use rtopk::util::chunkpool::{ChunkPool, SELECT_CHUNK};
use rtopk::prop_assert;
use rtopk::sparsify::{
    l2_sq, select_top_r, CompressionOperator, ErrorFeedback, NoCompression, RTopK, RandomK,
    SparseVec, TopK,
};
use rtopk::util::proptest::{check, default_cases, gen_kr, gen_vector};
use rtopk::util::rng::Rng;

fn ops_for(k: usize, r: usize) -> Vec<Box<dyn CompressionOperator>> {
    vec![
        Box::new(TopK::new(k)),
        Box::new(RandomK::new(k)),
        Box::new(RTopK::new(k, r)),
        Box::new(NoCompression),
    ]
}

#[test]
fn prop_operators_emit_sorted_unique_indices_within_dim() {
    check("sorted-unique", default_cases(), |rng| {
        let w = gen_vector(rng, 300);
        let (k, r) = gen_kr(rng, w.len());
        let mut out = SparseVec::default();
        for op in ops_for(k, r) {
            op.compress(&w, rng, &mut out);
            prop_assert!(out.dim == w.len(), "{}: dim mismatch", op.name());
            prop_assert!(
                out.idx.windows(2).all(|p| p[0] < p[1]),
                "{}: indices not sorted/unique: {:?}",
                op.name(),
                out.idx
            );
            prop_assert!(
                out.idx.iter().all(|&i| (i as usize) < w.len()),
                "{}: index out of range",
                op.name()
            );
        }
        Ok(())
    });
}

#[test]
fn prop_selection_operators_copy_values_verbatim() {
    check("values-verbatim", default_cases(), |rng| {
        let w = gen_vector(rng, 300);
        let (k, r) = gen_kr(rng, w.len());
        let mut out = SparseVec::default();
        for op in ops_for(k, r) {
            op.compress(&w, rng, &mut out);
            for (&i, &v) in out.idx.iter().zip(&out.val) {
                prop_assert!(
                    v == w[i as usize],
                    "{}: value at {i} is {v}, expected {}",
                    op.name(),
                    w[i as usize]
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_rtopk_support_subset_of_top_r_with_exactly_k() {
    check("rtopk-support", default_cases(), |rng| {
        let w = gen_vector(rng, 300);
        let (k, r) = gen_kr(rng, w.len());
        let op = RTopK::new(k, r);
        let mut out = SparseVec::default();
        op.compress(&w, rng, &mut out);
        prop_assert!(out.nnz() == k.min(w.len()), "nnz {} != k {}", out.nnz(), k);
        // Kept magnitudes can't be below the top-r cutoff magnitude (for
        // ties the index set may differ, magnitudes cannot).
        let mut scratch = Vec::new();
        let top = select_top_r(&w, r.min(w.len()), &mut scratch);
        let cutoff = top
            .iter()
            .map(|&i| w[i as usize].abs())
            .fold(f32::INFINITY, f32::min);
        for &i in &out.idx {
            prop_assert!(
                w[i as usize].abs() >= cutoff,
                "kept |{}| < top-r cutoff {cutoff}",
                w[i as usize]
            );
        }
        Ok(())
    });
}

#[test]
fn prop_compression_contraction_definition4() {
    check("contraction", default_cases() / 2, |rng| {
        let w = gen_vector(rng, 200);
        let (k, r) = gen_kr(rng, w.len());
        let norm = l2_sq(&w);
        let mut out = SparseVec::default();
        // deterministic: top-k satisfies the bound per-draw
        let op = TopK::new(k);
        op.compress(&w, rng, &mut out);
        let err = norm - out.l2_sq();
        prop_assert!(
            err <= (1.0 - op.gamma(w.len())) * norm + 1e-6 + 1e-9 * norm,
            "topk contraction violated: err={err} bound={}",
            (1.0 - op.gamma(w.len())) * norm
        );
        // randomized: average over repeats (Proposition 1 is in expectation)
        let op = RTopK::new(k, r);
        let trials = 60;
        let mut mean_err = 0.0;
        for _ in 0..trials {
            op.compress(&w, rng, &mut out);
            mean_err += (norm - out.l2_sq()) / trials as f64;
        }
        prop_assert!(
            mean_err <= (1.0 - op.gamma(w.len())) * norm * 1.15 + 1e-6,
            "rtopk mean contraction violated: {mean_err} vs {}",
            (1.0 - op.gamma(w.len())) * norm
        );
        Ok(())
    });
}

#[test]
fn prop_error_feedback_conserves_mass_exactly() {
    check("ef-conservation", default_cases(), |rng| {
        let dim = 1 + rng.index(200);
        let (k, r) = gen_kr(rng, dim);
        let mut ef = ErrorFeedback::new(dim);
        let op = RTopK::new(k, r);
        let mut out = SparseVec::default();
        for _ in 0..5 {
            let g: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 2.0)).collect();
            let m_before = ef.memory.clone();
            ef.step(&g, &op, rng, &mut out);
            let dense = out.to_dense();
            for j in 0..dim {
                let lhs = g[j] + m_before[j];
                let rhs = dense[j] + ef.memory[j];
                prop_assert!(lhs == rhs, "coord {j}: {lhs} != {rhs} (exact identity)");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_codec_roundtrip_all_formats() {
    check("codec-roundtrip", default_cases(), |rng| {
        let dim = 1 + rng.index(50_000);
        let nnz = rng.index(dim.min(2_000) + 1);
        let mut idx = rng.sample_indices(dim, nnz);
        idx.sort_unstable();
        let sv = SparseVec {
            dim,
            idx: idx.iter().map(|&i| i as u32).collect(),
            val: (0..nnz).map(|_| rng.normal_f32(0.0, 10.0)).collect(),
        };
        for indices in [IndexFormat::FixedWidth, IndexFormat::DeltaVarint] {
            let cfg = CodecConfig { values: ValueFormat::F32, indices };
            let mut buf = Vec::new();
            codec::encode(&sv, cfg, &mut buf);
            let mut back = SparseVec::default();
            codec::decode(&buf, &mut back).map_err(|e| e.to_string())?;
            prop_assert!(back == sv, "roundtrip mismatch for {indices:?}");
        }
        Ok(())
    });
}

#[test]
fn prop_pipeline_roundtrip_bit_exact_all_stage_combos() {
    // decompress(compress(w)) == the kept coordinates, bit-exactly, for
    // every value × index stage combination — across dims 1..=65537 and
    // adversarial inputs (all-zero vectors, empty selections). "Bit-exact"
    // means idx identical and every value equal to the value stage's
    // documented rounding (identity for f32, bf16 round-trip for bf16).
    check("pipeline-roundtrip", default_cases(), |rng| {
        let dim = match rng.index(6) {
            0 => 1,
            1 => 65_537,
            _ => 1 + rng.index(65_537),
        };
        let w: Vec<f32> = match rng.index(3) {
            0 => vec![0.0; dim], // all-zero gradient
            1 => (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
            _ => (0..dim)
                .map(|_| if rng.bernoulli(0.9) { 0.0 } else { rng.normal_f32(0.0, 5.0) })
                .collect(),
        };
        // k == 0 yields an empty message; k near dim exercises the
        // automatic bitmap index layout.
        let k = rng.index(dim.min(2048) + 1);
        let select = match rng.index(5) {
            0 => Select::top_k(k),
            1 => Select::random_k(k),
            2 => Select::approx_top_r(k, 1 + rng.index(256)),
            3 => Select::approx_top_r((2 * k).min(dim).max(1), 1 + rng.index(256))
                .then_random_k(k),
            _ => Select::top_r((2 * k).min(dim).max(1)).then_random_k(k),
        };
        for values in [ValueFormat::F32, ValueFormat::Bf16] {
            for indices in [IndexFormat::FixedWidth, IndexFormat::DeltaVarint] {
                let mut gc = GradientCompressor::builder(select.clone())
                    .values(values)
                    .indices(indices)
                    .build();
                let mut buf = Vec::new();
                let stats = gc.compress(&w, rng, &mut buf);
                prop_assert!(
                    stats.nnz == gc.kept().nnz(),
                    "stats nnz {} != kept {}",
                    stats.nnz,
                    gc.kept().nnz()
                );
                let mut back = SparseVec::default();
                GradientCompressor::decompress_into(&buf, &mut back)
                    .map_err(|e| e.to_string())?;
                prop_assert!(back.dim == dim, "dim {} != {dim}", back.dim);
                prop_assert!(
                    back.idx == gc.kept().idx,
                    "{values:?}/{indices:?}: index mismatch (dim {dim}, k {k})"
                );
                for (j, (&got, &sent)) in back.val.iter().zip(&gc.kept().val).enumerate() {
                    let expect = value_roundtrip(sent, values);
                    prop_assert!(
                        got.to_bits() == expect.to_bits(),
                        "{values:?}/{indices:?}: val[{j}] {got} != {expect}"
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_atopk_is_exact_and_thread_invariant() {
    // atopk must (a) return exactly min(r, d) sorted unique indices whose
    // magnitudes form a valid top-r set — min kept ≥ max dropped, the
    // paper's Definition-1 bar with ties broken arbitrarily — and (b)
    // produce bit-identical survivors for every `--select-threads` value,
    // because chunk boundaries, RNG draw order, and the chunk-order merge
    // are all independent of the pool size.
    check("atopk-exact-thread-invariant", default_cases(), |rng| {
        let dim = 1 + rng.index(100_000);
        let r = rng.index(dim.min(4_096) + 1);
        let sample = 1 + rng.index(8_192);
        let w: Vec<f32> = match rng.index(3) {
            0 => (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
            1 => vec![1.0; dim], // all-ties: the filter keeps everything
            _ => (0..dim)
                .map(|_| if rng.bernoulli(0.5) { 0.0 } else { rng.normal_f32(0.0, 5.0) })
                .collect(),
        };
        let sel = Select::approx_top_r(r, sample);
        let mut reference: Vec<u32> = Vec::new();
        for threads in [1usize, 2, 8] {
            // identical RNG stream per pool size via clone
            let mut run_rng = rng.clone();
            let mut s = SelectScratch::default();
            sel.apply_pooled(&w, &mut run_rng, &mut s, &ChunkPool::new(threads));
            if threads == 1 {
                reference = s.survivors.clone();
                prop_assert!(
                    reference.len() == r.min(dim),
                    "expected {} survivors, got {} (dim {dim}, sample {sample})",
                    r.min(dim),
                    reference.len()
                );
                prop_assert!(
                    reference.windows(2).all(|p| p[0] < p[1]),
                    "survivors not sorted/unique (dim {dim}, r {r})"
                );
                let mut kept = vec![false; dim];
                for &i in &reference {
                    kept[i as usize] = true;
                }
                let min_kept = reference
                    .iter()
                    .map(|&i| w[i as usize].abs())
                    .fold(f32::INFINITY, f32::min);
                let max_dropped = w
                    .iter()
                    .zip(&kept)
                    .filter(|&(_, &k)| !k)
                    .map(|(v, _)| v.abs())
                    .fold(0.0f32, f32::max);
                prop_assert!(
                    min_kept >= max_dropped,
                    "not a valid top-{r}: min kept {min_kept} < max dropped {max_dropped} \
                     (dim {dim}, sample {sample}, outcome {:?})",
                    s.last_atopk()
                );
            } else {
                prop_assert!(
                    s.survivors == reference,
                    "threads={threads} diverged from serial (dim {dim}, r {r}, \
                     sample {sample}, outcome {:?})",
                    s.last_atopk()
                );
            }
        }
        Ok(())
    });
}

#[test]
fn pipeline_roundtrip_empty_and_degenerate_dims() {
    // The deterministic corners the property above samples around: the
    // empty gradient (d = 0), d = 1, and the boundary dim 65537, each with
    // an all-zero vector, across every stage combo.
    let mut rng = Rng::new(0xE);
    for dim in [0usize, 1, 65_537] {
        let w = vec![0.0f32; dim];
        for values in [ValueFormat::F32, ValueFormat::Bf16] {
            for indices in [IndexFormat::FixedWidth, IndexFormat::DeltaVarint] {
                for select in [Select::all(), Select::top_k(4), Select::random_k(4)] {
                    let mut gc = GradientCompressor::builder(select)
                        .values(values)
                        .indices(indices)
                        .build();
                    let mut buf = Vec::new();
                    let stats = gc.compress(&w, &mut rng, &mut buf);
                    let mut back = SparseVec::default();
                    GradientCompressor::decompress_into(&buf, &mut back).unwrap();
                    assert_eq!(back.dim, dim);
                    assert_eq!(back.idx, gc.kept().idx, "dim {dim} {values:?}/{indices:?}");
                    assert_eq!(back.nnz(), stats.nnz);
                    assert!(back.val.iter().all(|&v| v == 0.0));
                }
            }
        }
    }
}

#[test]
fn prop_codec_never_larger_than_planned_size() {
    check("codec-size", default_cases(), |rng| {
        let dim = 1 + rng.index(100_000);
        let nnz = rng.index(dim.min(1_000) + 1);
        let mut idx = rng.sample_indices(dim, nnz);
        idx.sort_unstable();
        let sv = SparseVec {
            dim,
            idx: idx.iter().map(|&i| i as u32).collect(),
            val: vec![1.0; nnz],
        };
        let cfg = CodecConfig::default();
        let mut buf = Vec::new();
        codec::encode(&sv, cfg, &mut buf);
        prop_assert!(
            buf.len() <= codec::encoded_size(dim, nnz, cfg),
            "encoded {} > planned {}",
            buf.len(),
            codec::encoded_size(dim, nnz, cfg)
        );
        Ok(())
    });
}

#[test]
fn prop_aggregation_equals_average_of_decoded_messages() {
    check("aggregation-linearity", default_cases() / 2, |rng| {
        let dim = 1 + rng.index(500);
        let n = 1 + rng.index(8);
        let mut dense_sum = vec![0.0f64; dim];
        let mut agg = vec![0.0f32; dim];
        let scale = 1.0 / n as f32;
        for _ in 0..n {
            let (k, r) = gen_kr(rng, dim);
            let op = RTopK::new(k, r);
            let g: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 2.0)).collect();
            let mut out = SparseVec::default();
            op.compress(&g, rng, &mut out);
            // transport roundtrip
            let mut buf = Vec::new();
            codec::encode(&out, CodecConfig::default(), &mut buf);
            let mut back = SparseVec::default();
            codec::decode(&buf, &mut back).map_err(|e| e.to_string())?;
            back.add_scaled_into(scale, &mut agg);
            for (&i, &v) in out.idx.iter().zip(&out.val) {
                dense_sum[i as usize] += v as f64 / n as f64;
            }
        }
        for j in 0..dim {
            prop_assert!(
                (agg[j] as f64 - dense_sum[j]).abs() < 1e-4 * dense_sum[j].abs().max(1.0),
                "coord {j}: {} vs {}",
                agg[j],
                dense_sum[j]
            );
        }
        Ok(())
    });
}

#[test]
fn prop_topk_keeps_the_heaviest_mass() {
    // ||top_k(w)||^2 >= ||any other k-selection||^2, in particular random-k.
    check("topk-heaviest", default_cases(), |rng| {
        let w = gen_vector(rng, 300);
        let k = 1 + rng.index(w.len());
        let mut a = SparseVec::default();
        let mut b = SparseVec::default();
        TopK::new(k).compress(&w, rng, &mut a);
        RandomK::new(k).compress(&w, rng, &mut b);
        prop_assert!(
            a.l2_sq() >= b.l2_sq() - 1e-9,
            "topk mass {} < randomk mass {}",
            a.l2_sq(),
            b.l2_sq()
        );
        Ok(())
    });
}

#[test]
fn prop_warmup_schedule_monotone_and_bounded() {
    check("warmup-monotone", default_cases(), |rng| {
        let target = 10f64.powf(-(1.0 + 3.0 * rng.f64())); // 1e-1 .. 1e-4
        let epochs = 1 + rng.index(10);
        let w = rtopk::optim::WarmupSparsity::new(target, epochs as f64);
        let mut prev = f64::INFINITY;
        for i in 0..=(epochs * 4) {
            let e = i as f64 / 2.0;
            let f = w.keep_frac(e);
            prop_assert!(f <= prev + 1e-12, "not monotone at {e}");
            prop_assert!(f >= target - 1e-15 && f <= 1.0, "out of bounds at {e}: {f}");
            prev = f;
        }
        prop_assert!(
            (w.keep_frac(epochs as f64) - target).abs() < 1e-12,
            "did not reach target"
        );
        Ok(())
    });
}

#[test]
fn prop_select_top_r_magnitudes_dominate_rest() {
    check("select-dominates", default_cases(), |rng| {
        let w = gen_vector(rng, 400);
        let r = 1 + rng.index(w.len());
        let mut scratch = Vec::new();
        let top: std::collections::HashSet<u32> =
            select_top_r(&w, r, &mut scratch).into_iter().collect();
        let min_in = top
            .iter()
            .map(|&i| w[i as usize].abs())
            .fold(f32::INFINITY, f32::min);
        for i in 0..w.len() as u32 {
            if !top.contains(&i) {
                prop_assert!(
                    w[i as usize].abs() <= min_in + 1e-9,
                    "excluded |{}| > included min {min_in}",
                    w[i as usize]
                );
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Partitioned (layerwise) pipeline invariants: random layouts × every
// value/index stage combo, roundtrip + flat bit-identity + per-segment
// error-feedback conservation (DESIGN.md §7).
// ---------------------------------------------------------------------------

/// A random contiguous partition of [0, dim) into 1..=6 non-empty segments.
fn random_layout(rng: &mut Rng, dim: usize) -> SegmentLayout {
    let nseg = 1 + rng.index(dim.min(6));
    let mut cuts = rng.sample_indices(dim - 1, nseg - 1);
    cuts.sort_unstable();
    let mut parts = Vec::new();
    let mut prev = 0usize;
    for (i, &c) in cuts.iter().enumerate() {
        parts.push((format!("s{i}"), c + 1 - prev));
        prev = c + 1;
    }
    parts.push((format!("s{}", nseg - 1), dim - prev));
    SegmentLayout::from_parts(&parts).unwrap()
}

fn spec_with_wire(select: &str, values: ValueFormat, indices: IndexFormat) -> PipelineSpec {
    let mut spec = PipelineSpec::parse(select).unwrap();
    spec.values = values;
    spec.indices = indices;
    spec
}

#[test]
fn prop_partitioned_roundtrip_random_layouts_all_stage_combos() {
    // (a) what the wire decodes == what the compressor kept, per segment
    // and globally, for every value × index combo over random layouts and
    // adversarial dims (1, the 16-bit-boundary 65537, random).
    check("partitioned-roundtrip", default_cases() / 2, |rng| {
        let dim = match rng.index(4) {
            0 => 1,
            1 => 65_537,
            _ => 2 + rng.index(5_000),
        };
        let layout = random_layout(rng, dim);
        let w: Vec<f32> = match rng.index(3) {
            0 => vec![0.0; dim],
            1 => (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
            _ => (0..dim)
                .map(|_| if rng.bernoulli(0.9) { 0.0 } else { rng.normal_f32(0.0, 5.0) })
                .collect(),
        };
        let k = rng.index(dim.min(1024) + 1);
        let select = ["topk", "randomk", "rtopk", "atopk:r=2k,sample=256>random"][rng.index(4)];
        let policy = [BudgetPolicy::Proportional, BudgetPolicy::Uniform, BudgetPolicy::Adaptive]
            [rng.index(3)];
        for values in [ValueFormat::F32, ValueFormat::Bf16] {
            for indices in [IndexFormat::FixedWidth, IndexFormat::DeltaVarint] {
                let spec = spec_with_wire(select, values, indices);
                let mut pc =
                    PartitionedCompressor::new(&spec, layout.clone(), policy, k, 0.2);
                let mut buf = Vec::new();
                let stats = pc.compress(&w, rng, &mut buf);
                prop_assert!(
                    stats.payload_bytes == buf.len(),
                    "stats bytes {} != {}",
                    stats.payload_bytes,
                    buf.len()
                );
                let mut back = SparseVec::default();
                codec::decode_expecting(&buf, Some(dim), &mut back)
                    .map_err(|e| e.to_string())?;
                prop_assert!(
                    &back == pc.kept(),
                    "{select}/{values:?}/{indices:?}: decode != kept \
                     (dim {dim}, k {k}, {} segments)",
                    layout.len()
                );
                prop_assert!(
                    back.nnz() == stats.nnz,
                    "nnz mismatch: {} vs {}",
                    back.nnz(),
                    stats.nnz
                );
                // per-segment budgets sum exactly to the allocated total
                let alloc_sum: usize = pc.alloc().iter().sum();
                prop_assert!(
                    alloc_sum == k.clamp(1, dim),
                    "budget drift: Σ alloc {alloc_sum} != {}",
                    k.clamp(1, dim)
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_partitioned_single_segment_byte_identical_to_flat() {
    // (b) a single-segment layout IS the flat pipeline: same bytes on the
    // wire, same kept record, same RNG consumption.
    check("partitioned-flat-identity", default_cases() / 2, |rng| {
        let dim = 1 + rng.index(10_000);
        let k = rng.index(dim.min(512) + 1).max(1);
        let select = ["topk", "randomk", "rtopk", "atopk:r=2k,sample=256>random"][rng.index(4)];
        for values in [ValueFormat::F32, ValueFormat::Bf16] {
            for indices in [IndexFormat::FixedWidth, IndexFormat::DeltaVarint] {
                let spec = spec_with_wire(select, values, indices);
                let w: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                let layout = SegmentLayout::single(dim).map_err(|e| e.to_string())?;
                let mut pc = PartitionedCompressor::new(
                    &spec,
                    layout,
                    BudgetPolicy::Proportional,
                    k,
                    0.2,
                );
                let mut gc = spec.build(k.clamp(1, dim), 0.2, dim);
                // identical RNG streams via clone
                let mut ra = rng.clone();
                let mut rb = rng.clone();
                let (mut a, mut b) = (Vec::new(), Vec::new());
                pc.compress(&w, &mut ra, &mut a);
                gc.compress(&w, &mut rb, &mut b);
                prop_assert!(
                    a == b,
                    "{select}/{values:?}/{indices:?}: single-segment bytes differ \
                     (dim {dim}, k {k})"
                );
                prop_assert!(pc.kept() == gc.kept(), "kept record differs");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_partitioned_error_feedback_conserves_mass_per_segment() {
    // (c) g + m == ĝ + m' holds bitwise on every coordinate — hence
    // exactly within every segment — across rounds, layouts, and value
    // stages (bf16 rounding re-enters via the kept record).
    check("partitioned-ef-conservation", default_cases() / 2, |rng| {
        let dim = 2 + rng.index(400);
        let layout = random_layout(rng, dim);
        let k = 1 + rng.index(dim.min(64));
        let values = if rng.bernoulli(0.5) { ValueFormat::F32 } else { ValueFormat::Bf16 };
        let spec = spec_with_wire("rtopk", values, IndexFormat::FixedWidth);
        let mut pc = PartitionedCompressor::new(
            &spec,
            layout,
            BudgetPolicy::Proportional,
            k,
            0.2,
        );
        let mut ef = ErrorFeedback::new(dim);
        let mut buf = Vec::new();
        for round in 0..4 {
            let g: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 2.0)).collect();
            let m_before = ef.memory.clone();
            let acc = ef.compensate(&g).to_vec();
            pc.compress(&acc, rng, &mut buf);
            ef.update_residual(pc.kept());
            let mut back = SparseVec::default();
            codec::decode_expecting(&buf, Some(dim), &mut back).map_err(|e| e.to_string())?;
            let applied = back.to_dense();
            for j in 0..dim {
                let lhs = g[j] + m_before[j];
                let rhs = applied[j] + ef.memory[j];
                prop_assert!(
                    lhs.to_bits() == rhs.to_bits(),
                    "round {round} coord {j}: {lhs} != {rhs} ({values:?})"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn partitioned_roundtrip_boundary_dims() {
    // The deterministic corners around segment boundaries: a coordinate on
    // each side of every cut (boundary ± 1), dim 1, and the 16-bit
    // boundary 65537 split as [65536, 1]. (dim 0 has no non-empty
    // partition — the flat pipeline owns it, covered by
    // `pipeline_roundtrip_empty_and_degenerate_dims`.)
    let mut rng = Rng::new(0x5E6);
    for (dim, parts) in [
        (1usize, vec![1usize]),
        (7, vec![3, 4]),
        (65_537, vec![65_536, 1]),
        (64, vec![1, 31, 32]),
    ] {
        let named: Vec<(String, usize)> =
            parts.iter().enumerate().map(|(i, &l)| (format!("s{i}"), l)).collect();
        let layout = SegmentLayout::from_parts(&named).unwrap();
        // values spike exactly at each boundary and its neighbours
        let mut w = vec![0.0f32; dim];
        let mut mark = |i: usize| {
            if i < dim {
                w[i] = 1.0 + i as f32;
            }
        };
        let mut off = 0usize;
        for &l in &parts {
            off += l;
            mark(off.wrapping_sub(1));
            mark(off);
            mark(off + 1);
        }
        mark(0);
        for (values, indices) in [
            (ValueFormat::F32, IndexFormat::FixedWidth),
            (ValueFormat::F32, IndexFormat::DeltaVarint),
            (ValueFormat::Bf16, IndexFormat::FixedWidth),
            (ValueFormat::Bf16, IndexFormat::DeltaVarint),
        ] {
            let spec = spec_with_wire("topk", values, indices);
            let mut pc = PartitionedCompressor::new(
                &spec,
                layout.clone(),
                BudgetPolicy::Proportional,
                dim.min(16),
                0.2,
            );
            let mut buf = Vec::new();
            pc.compress(&w, &mut rng, &mut buf);
            let mut back = SparseVec::default();
            codec::decode_expecting(&buf, Some(dim), &mut back).unwrap();
            back.debug_validate();
            assert_eq!(&back, pc.kept(), "dim {dim} {values:?}/{indices:?}");
        }
    }
}

// ---------------------------------------------------------------------------
// Decode robustness: arbitrary and corrupted payloads must produce errors,
// never panics — and with an expected dimension, never allocations past it.
// Covers the bounded decode path the transport uses (leader uplink and the
// delta downlink both decode with `decode_expecting`).
// ---------------------------------------------------------------------------

/// Invariants any successful decode must uphold, whatever the input bytes.
fn assert_decoded_invariants(sv: &SparseVec, expected_dim: Option<usize>) -> Result<(), String> {
    if let Some(d) = expected_dim {
        prop_assert!(sv.dim == d, "decoded dim {} != expected {d}", sv.dim);
        prop_assert!(sv.nnz() <= d, "nnz {} past expected dim {d}", sv.nnz());
    }
    prop_assert!(
        sv.idx.len() == sv.val.len(),
        "idx/val length skew: {} vs {}",
        sv.idx.len(),
        sv.val.len()
    );
    prop_assert!(
        sv.idx.iter().all(|&i| (i as usize) < sv.dim),
        "decoded index out of range"
    );
    prop_assert!(
        sv.idx.windows(2).all(|w| w[0] < w[1]),
        "decoded indices not sorted unique"
    );
    Ok(())
}

#[test]
fn prop_decode_random_garbage_errors_never_panics() {
    check("decode-garbage", default_cases() * 4, |rng| {
        let expected_dim = 1 + rng.index(10_000);
        let len = rng.index(512);
        let mut buf: Vec<u8> = (0..len).map(|_| rng.index(256) as u8).collect();
        // most cases get a valid magic so the parser goes deeper than the
        // first two bytes — flat ("RT") or segmented ("SG")
        if buf.len() >= 2 {
            match rng.index(3) {
                0 => {
                    buf[0] = 0x54;
                    buf[1] = 0x52;
                }
                1 => {
                    buf[0] = 0x53;
                    buf[1] = 0x47;
                }
                _ => {}
            }
        }
        let mut sv = SparseVec::default();
        match codec::decode_expecting(&buf, Some(expected_dim), &mut sv) {
            Err(_) => {}
            Ok(()) => assert_decoded_invariants(&sv, Some(expected_dim))?,
        }
        // the unchecked-dim entry point must also never panic, and is
        // still bounded by the buffer it was given
        let mut sv2 = SparseVec::default();
        if codec::decode(&buf, &mut sv2).is_ok() {
            assert_decoded_invariants(&sv2, None)?;
            prop_assert!(
                sv2.nnz() * 2 <= buf.len(),
                "claimed nnz {} not backed by {} payload bytes",
                sv2.nnz(),
                buf.len()
            );
        }
        Ok(())
    });
}

#[test]
fn prop_decode_bitflipped_frames_error_or_stay_sane() {
    check("decode-bitflip", default_cases() * 2, |rng| {
        let dim = 1 + rng.index(50_000);
        let nnz = rng.index(dim.min(500) + 1);
        let mut idx = rng.sample_indices(dim, nnz);
        idx.sort_unstable();
        let sv = SparseVec {
            dim,
            idx: idx.iter().map(|&i| i as u32).collect(),
            val: (0..nnz).map(|_| rng.normal_f32(0.0, 5.0)).collect(),
        };
        let indices = if rng.bernoulli(0.5) {
            IndexFormat::FixedWidth
        } else {
            IndexFormat::DeltaVarint
        };
        let values = if rng.bernoulli(0.5) { ValueFormat::F32 } else { ValueFormat::Bf16 };
        let mut buf = Vec::new();
        codec::encode(&sv, CodecConfig { values, indices }, &mut buf);
        // flip 1..=4 random bits anywhere in the frame
        for _ in 0..1 + rng.index(4) {
            let bit = rng.index(buf.len() * 8);
            buf[bit / 8] ^= 1 << (bit % 8);
        }
        let mut back = SparseVec::default();
        match codec::decode_expecting(&buf, Some(dim), &mut back) {
            // a flip in the values region (or one that cancels out) can
            // still decode; it must just never violate the structural
            // invariants or panic
            Ok(()) => assert_decoded_invariants(&back, Some(dim))?,
            Err(_) => {}
        }
        Ok(())
    });
}

#[test]
fn prop_segmented_frames_bitflip_truncate_never_panic() {
    // Real segmented frames with injected corruption: bit-flips anywhere
    // (header, table, bodies) and strict prefixes must error or decode to
    // a structurally sane vector — never panic, never allocate past the
    // expected dimension.
    check("segmented-bitflip", default_cases() * 2, |rng| {
        let dim = 8 + rng.index(20_000);
        let layout = random_layout(rng, dim);
        let w: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let values = if rng.bernoulli(0.5) { ValueFormat::F32 } else { ValueFormat::Bf16 };
        let indices = if rng.bernoulli(0.5) {
            IndexFormat::FixedWidth
        } else {
            IndexFormat::DeltaVarint
        };
        let spec = spec_with_wire("topk", values, indices);
        let mut pc = PartitionedCompressor::new(
            &spec,
            layout,
            BudgetPolicy::Proportional,
            1 + rng.index(dim.min(300)),
            0.2,
        );
        let mut buf = Vec::new();
        pc.compress(&w, rng, &mut buf);
        let mut back = SparseVec::default();
        // any strict prefix fails (table or a sub-payload gets starved)
        let cut = rng.index(buf.len());
        prop_assert!(
            codec::decode_expecting(&buf[..cut], Some(dim), &mut back).is_err(),
            "prefix of {cut}/{} bytes decoded",
            buf.len()
        );
        // flip 1..=4 random bits; decode must error or stay sane
        let mut evil = buf.clone();
        for _ in 0..1 + rng.index(4) {
            let bit = rng.index(evil.len() * 8);
            evil[bit / 8] ^= 1 << (bit % 8);
        }
        match codec::decode_expecting(&evil, Some(dim), &mut back) {
            Ok(()) => assert_decoded_invariants(&back, Some(dim))?,
            Err(_) => {}
        }
        Ok(())
    });
}

#[test]
fn prop_truncated_frames_error() {
    check("decode-truncated", default_cases(), |rng| {
        let dim = 1 + rng.index(20_000);
        let nnz = 1 + rng.index(dim.min(300));
        let mut idx = rng.sample_indices(dim, nnz);
        idx.sort_unstable();
        let sv = SparseVec {
            dim,
            idx: idx.iter().map(|&i| i as u32).collect(),
            val: vec![1.0; nnz],
        };
        let mut buf = Vec::new();
        codec::encode(&sv, CodecConfig::default(), &mut buf);
        // any strict prefix must fail (the values tail backs the claimed
        // nnz, so dropping bytes starves either indices or values)
        let cut = rng.index(buf.len());
        let mut back = SparseVec::default();
        prop_assert!(
            codec::decode_expecting(&buf[..cut], Some(dim), &mut back).is_err(),
            "prefix of {cut}/{} bytes decoded",
            buf.len()
        );
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Tree-fold (hierarchical aggregation) reduction contract — DESIGN.md §8.
// ---------------------------------------------------------------------------

/// A sparse vector whose values are wire-exact for the given value stage
/// (what a relay actually receives after decoding a child's frame).
fn random_sparse_wire(rng: &mut Rng, dim: usize, values: ValueFormat) -> SparseVec {
    let k = 1 + rng.index(dim.min(64));
    let mut idx = rng.sample_indices(dim, k);
    idx.sort_unstable();
    SparseVec {
        dim,
        idx: idx.iter().map(|&i| i as u32).collect(),
        val: (0..k)
            .map(|_| value_roundtrip(rng.normal_f32(0.0, 1.0), values))
            .collect(),
    }
}

/// A random contiguous in-order partition of `0..n` (what any tree
/// topology induces over its leaf ranges).
fn random_contiguous_groups(rng: &mut Rng, n: usize) -> Vec<std::ops::Range<usize>> {
    let mut cuts = vec![0, n];
    for _ in 0..rng.index(n) {
        cuts.push(rng.index(n + 1));
    }
    cuts.sort_unstable();
    cuts.dedup();
    cuts.windows(2).map(|w| w[0]..w[1]).collect()
}

/// Per-coordinate magnitude scale for fp tolerances: the flat fold of the
/// ABSOLUTE values (cancellation can make the result tiny while the
/// operands are large, so tolerances must be relative to the operands).
fn abs_magnitude(inputs: &[SparseVec], scale: f32, dim: usize) -> SparseVec {
    let abs_inputs: Vec<SparseVec> = inputs
        .iter()
        .map(|sv| SparseVec {
            dim,
            idx: sv.idx.clone(),
            val: sv.val.iter().map(|v| v.abs()).collect(),
        })
        .collect();
    let mut mag = SparseVec::default();
    merge_scaled_into(&abs_inputs, scale.abs(), dim, &mut mag);
    mag
}

#[test]
fn prop_tree_fold_singletons_bit_exact_arbitrary_groups_within_tolerance() {
    check("tree-fold", default_cases(), |rng| {
        let dim = 1 + rng.index(500);
        let n = 1 + rng.index(8);
        let values = if rng.bernoulli(0.5) { ValueFormat::F32 } else { ValueFormat::Bf16 };
        let inputs: Vec<SparseVec> =
            (0..n).map(|_| random_sparse_wire(rng, dim, values)).collect();
        let scale = 1.0 / n as f32;
        let mut flat = SparseVec::default();
        merge_scaled_into(&inputs, scale, dim, &mut flat);

        // all-singleton grouping IS the flat fold: bit-exact, any scale
        let singles: Vec<_> = (0..n).map(|i| i..i + 1).collect();
        let mut tree = SparseVec::default();
        merge_tree_scaled_into(&inputs, &singles, scale, dim, &mut tree);
        prop_assert!(flat.idx == tree.idx, "singleton grouping changed the support");
        for (j, (a, b)) in flat.val.iter().zip(&tree.val).enumerate() {
            prop_assert!(
                a.to_bits() == b.to_bits(),
                "singleton groups must be bit-exact at entry {j}: {a} vs {b}"
            );
        }

        // arbitrary contiguous grouping: identical support, deterministic,
        // values within the documented fp tolerance of the flat fold
        let groups = random_contiguous_groups(rng, n);
        let mut t1 = SparseVec::default();
        let mut t2 = SparseVec::default();
        merge_tree_scaled_into(&inputs, &groups, scale, dim, &mut t1);
        merge_tree_scaled_into(&inputs, &groups, scale, dim, &mut t2);
        prop_assert!(
            t1.idx == t2.idx
                && t1.val.iter().zip(&t2.val).all(|(a, b)| a.to_bits() == b.to_bits()),
            "tree fold must be deterministic for groups {groups:?}"
        );
        prop_assert!(t1.idx == flat.idx, "grouping must not change the union support");
        let mag = abs_magnitude(&inputs, scale, dim);
        for (j, (a, b)) in flat.val.iter().zip(&t1.val).enumerate() {
            let tol = 1e-4f32 * mag.val[j].max(1e-6);
            prop_assert!(
                (a - b).abs() <= tol,
                "groups {groups:?} coord {}: flat {a} vs tree {b} (tol {tol})",
                flat.idx[j]
            );
        }
        Ok(())
    });
}

#[test]
fn prop_tree_fold_bit_exact_for_group_local_supports_with_pow2_scale() {
    // Contiguous in-order child ranges whose supports never span a group
    // boundary (each group owns its own index subrange — the layerwise
    // regime), reduced at a power-of-two scale (the FullSync 1/n for
    // power-of-two n): the tree fold must equal the flat fold bit for bit.
    check("tree-fold-group-local", default_cases(), |rng| {
        let n_groups = 1 + rng.index(4);
        let per_group = 1 + rng.index(3);
        let seg = 32usize;
        let dim = n_groups * seg;
        let values = if rng.bernoulli(0.5) { ValueFormat::F32 } else { ValueFormat::Bf16 };
        let mut inputs: Vec<SparseVec> = Vec::new();
        let mut groups: Vec<std::ops::Range<usize>> = Vec::new();
        for g in 0..n_groups {
            let start = inputs.len();
            for _ in 0..per_group {
                let local = random_sparse_wire(rng, seg, values);
                inputs.push(SparseVec {
                    dim,
                    idx: local.idx.iter().map(|&i| i + (g * seg) as u32).collect(),
                    val: local.val,
                });
            }
            groups.push(start..inputs.len());
        }
        let scale = [1.0f32, 0.5, 0.25, 0.125][rng.index(4)];
        let mut flat = SparseVec::default();
        let mut tree = SparseVec::default();
        merge_scaled_into(&inputs, scale, dim, &mut flat);
        merge_tree_scaled_into(&inputs, &groups, scale, dim, &mut tree);
        prop_assert!(flat.idx == tree.idx, "support mismatch");
        for (j, (a, b)) in flat.val.iter().zip(&tree.val).enumerate() {
            prop_assert!(
                a.to_bits() == b.to_bits(),
                "group-local supports at pow2 scale {scale} must be bit-exact at entry \
                 {j}: {a} vs {b}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_simulated_relay_path_matches_tree_fold_reference() {
    // The distributed contract, simulated locally: per-group scale-1.0
    // merge → encode → decode (the wire) → flat merge of the decoded
    // frames at the root's scale. With an f32 value stage the wire is
    // lossless and the result must equal `merge_tree_scaled_into` bit for
    // bit (any index stage); with bf16 the relay's re-encode re-rounds the
    // partial sums, bounded by bf16's relative eps per hop.
    check("relay-path", default_cases(), |rng| {
        let dim = 1 + rng.index(400);
        let n = 2 + rng.index(6);
        for (values, indices) in [
            (ValueFormat::F32, IndexFormat::FixedWidth),
            (ValueFormat::F32, IndexFormat::DeltaVarint),
            (ValueFormat::Bf16, IndexFormat::FixedWidth),
            (ValueFormat::Bf16, IndexFormat::DeltaVarint),
        ] {
            let wire = CodecConfig { values, indices };
            let inputs: Vec<SparseVec> =
                (0..n).map(|_| random_sparse_wire(rng, dim, values)).collect();
            let groups = random_contiguous_groups(rng, n);
            let mut relay_frames: Vec<SparseVec> = Vec::new();
            for g in &groups {
                let mut union = SparseVec::default();
                merge_scaled_into(&inputs[g.clone()], 1.0, dim, &mut union);
                let mut buf = Vec::new();
                codec::encode(&union, wire, &mut buf);
                let mut back = SparseVec::default();
                codec::decode_expecting(&buf, Some(dim), &mut back)
                    .map_err(|e| format!("relay frame decode failed: {e:?}"))?;
                relay_frames.push(back);
            }
            let scale = 1.0 / n as f32;
            let mut root = SparseVec::default();
            merge_scaled_into(&relay_frames, scale, dim, &mut root);
            let mut reference = SparseVec::default();
            merge_tree_scaled_into(&inputs, &groups, scale, dim, &mut reference);
            prop_assert!(
                root.idx == reference.idx,
                "wire round-trip changed the union support ({values:?}/{indices:?})"
            );
            match values {
                ValueFormat::F32 => {
                    for (j, (a, b)) in reference.val.iter().zip(&root.val).enumerate() {
                        prop_assert!(
                            a.to_bits() == b.to_bits(),
                            "f32 relay path must be bit-exact at entry {j}: {a} vs {b} \
                             ({indices:?}, groups {groups:?})"
                        );
                    }
                }
                ValueFormat::Bf16 => {
                    let mag = abs_magnitude(&inputs, scale, dim);
                    for (j, (a, b)) in reference.val.iter().zip(&root.val).enumerate() {
                        let tol = 0.01f32 * mag.val[j].max(1e-6);
                        prop_assert!(
                            (a - b).abs() <= tol,
                            "bf16 relay path entry {j}: ref {a} vs wire {b} (tol {tol})"
                        );
                    }
                }
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Range-partitioned parallel aggregation ≡ serial, bit for bit, for any
// thread count (DESIGN.md §13).
// ---------------------------------------------------------------------------

/// A sparse vector whose support is biased onto the [`SELECT_CHUNK`] range
/// boundaries, so the parallel merge's binary-searched cursor starts and
/// range hand-offs are actually exercised (uniform sampling at dim ~65537
/// almost never lands on the one coordinate in the second range).
fn boundary_sparse(rng: &mut Rng, dim: usize) -> SparseVec {
    let k = 1 + rng.index(dim.min(64));
    let mut idx: Vec<u32> = rng.sample_indices(dim, k).iter().map(|&i| i as u32).collect();
    for b in [0, SELECT_CHUNK - 1, SELECT_CHUNK, SELECT_CHUNK + 1, dim - 1] {
        if b < dim && rng.bernoulli(0.5) {
            idx.push(b as u32);
        }
    }
    idx.sort_unstable();
    idx.dedup();
    let val = idx.iter().map(|_| rng.normal_f32(0.0, 1.0)).collect();
    SparseVec { dim, idx, val }
}

#[test]
fn prop_pooled_merge_bit_identical_to_serial_for_any_thread_count() {
    check("pooled-merge", default_cases(), |rng| {
        // dims straddle the range boundary: 1, 65535, 65536, 65537, multi
        let dims = [1, SELECT_CHUNK - 1, SELECT_CHUNK, SELECT_CHUNK + 1, 3 * SELECT_CHUNK + 17];
        let dim = dims[rng.index(dims.len())];
        // n = 0 is the empty-input corner
        let n = rng.index(6);
        let mut inputs: Vec<SparseVec> = (0..n).map(|_| boundary_sparse(rng, dim)).collect();
        if n >= 2 && rng.bernoulli(0.3) {
            // all-overlap corner: every worker shares worker 0's support,
            // so every coordinate folds across all n inputs
            let base_idx = inputs[0].idx.clone();
            for sv in &mut inputs[1..] {
                sv.val = base_idx.iter().map(|_| rng.normal_f32(0.0, 1.0)).collect();
                sv.idx = base_idx.clone();
            }
        }
        let scale = 1.0 / n.max(1) as f32;
        let mut serial = SparseVec::default();
        merge_scaled_into(&inputs, scale, dim, &mut serial);
        let mut scratch = MergeScratch::default();
        for threads in [1, 2, 3, 8] {
            let pool = ChunkPool::new(threads);
            let mut pooled = SparseVec::default();
            merge_scaled_into_pooled(&inputs, scale, dim, &mut pooled, &pool, &mut scratch);
            prop_assert!(
                pooled.idx == serial.idx,
                "threads={threads} dim={dim} n={n}: support mismatch"
            );
            prop_assert!(
                pooled.val.iter().zip(&serial.val).all(|(a, b)| a.to_bits() == b.to_bits()),
                "threads={threads} dim={dim} n={n}: values not bit-identical"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_pooled_tree_merge_bit_identical_to_serial() {
    check("pooled-tree-merge", default_cases(), |rng| {
        let dims = [1, SELECT_CHUNK - 1, SELECT_CHUNK + 1, 2 * SELECT_CHUNK + 5];
        let dim = dims[rng.index(dims.len())];
        let n = 1 + rng.index(8);
        let inputs: Vec<SparseVec> = (0..n).map(|_| boundary_sparse(rng, dim)).collect();
        let groups = random_contiguous_groups(rng, n);
        let scale = 1.0 / n as f32;
        let mut serial = SparseVec::default();
        merge_tree_scaled_into(&inputs, &groups, scale, dim, &mut serial);
        let mut scratch = TreeMergeScratch::default();
        for threads in [1, 2, 3, 8] {
            let pool = ChunkPool::new(threads);
            let mut pooled = SparseVec::default();
            merge_tree_scaled_into_pooled(
                &inputs,
                &groups,
                scale,
                dim,
                &mut pooled,
                &pool,
                &mut scratch,
            );
            prop_assert!(
                pooled.idx == serial.idx,
                "threads={threads} dim={dim} groups={groups:?}: support mismatch"
            );
            prop_assert!(
                pooled.val.iter().zip(&serial.val).all(|(a, b)| a.to_bits() == b.to_bits()),
                "threads={threads} dim={dim} groups={groups:?}: values not bit-identical"
            );
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Federation invariants: lazy population shards and per-round cohort
// sampling over a registered-client population (DESIGN.md §9).
// ---------------------------------------------------------------------------

#[test]
fn prop_population_sharder_is_deterministic_and_in_range() {
    check("sharder-deterministic", default_cases(), |rng| {
        let n_groups = 1 + rng.index(16);
        let n_examples = n_groups + rng.index(10_000);
        let skew = rng.f64();
        let seed = rng.next_u64();
        let s = PopulationSharder::new(n_examples, n_groups, skew, seed);
        let s2 = PopulationSharder::new(n_examples, n_groups, skew, seed);
        for _ in 0..32 {
            let client = rng.next_u64() % 1_000_000;
            let step = rng.next_u64() % 10_000;
            let a = s.draw(client, step);
            prop_assert!(a == s2.draw(client, step), "draw must be a pure function");
            prop_assert!(a == s.draw(client, step), "draw must not keep state");
            prop_assert!(a < n_examples, "draw {a} out of range {n_examples}");
            let g = s.home_group(client);
            prop_assert!(g < n_groups, "home group {g} out of range");
            prop_assert!(g == s2.home_group(client), "home group must be stable");
        }
        // group blocks tile [0, n_examples) exactly: no client materialises
        // a shard, yet every example is owned by exactly one group
        let mut covered = 0usize;
        for g in 0..n_groups {
            let (start, len) = s.group_block(g);
            prop_assert!(start == covered, "block {g} starts at {start}, expected {covered}");
            prop_assert!(len >= 1, "block {g} is empty");
            covered = start + len;
        }
        prop_assert!(covered == n_examples, "blocks cover {covered} != {n_examples}");
        Ok(())
    });
}

#[test]
fn prop_population_sharder_skew_extremes() {
    check("sharder-skew", default_cases(), |rng| {
        let n_groups = 2 + rng.index(8);
        let n_examples = n_groups * (1 + rng.index(500));
        let seed = rng.next_u64();
        // skew 1: every draw stays inside the client's home block
        let hard = PopulationSharder::new(n_examples, n_groups, 1.0, seed);
        for _ in 0..16 {
            let client = rng.next_u64() % 10_000;
            let (start, len) = hard.group_block(hard.home_group(client));
            let i = hard.draw(client, rng.next_u64() % 1_000);
            prop_assert!(i >= start && i < start + len, "skew=1 draw {i} left home block");
        }
        // skew 0: draws from many clients reach beyond any single block
        let iid_sharder = PopulationSharder::new(n_examples, n_groups, 0.0, seed);
        let mut groups_hit = std::collections::HashSet::new();
        for c in 0..64u64 {
            let i = iid_sharder.draw(c, 0);
            let g = (0..n_groups)
                .find(|&g| {
                    let (start, len) = iid_sharder.group_block(g);
                    i >= start && i < start + len
                })
                .unwrap();
            groups_hit.insert(g);
        }
        prop_assert!(groups_hit.len() >= 2, "skew=0 draws collapsed to one group");
        Ok(())
    });
}

#[test]
fn prop_cohort_sampler_deterministic_sorted_distinct_in_range() {
    check("cohort-sampler", default_cases(), |rng| {
        let cohort = 1 + rng.index(64);
        let population = cohort + rng.index(10_000);
        let run_seed = rng.next_u64();
        let round = rng.next_u64() % 1_000;
        for sampler in [
            SamplerKind::Uniform,
            SamplerKind::Weighted,
            SamplerKind::Availability { p: 0.01 + 0.99 * rng.f64() },
        ] {
            let mut fed = FederationConfig::new(population, cohort, 1);
            fed.sampler = sampler;
            fed.population_seed = run_seed;
            let a = CohortSampler::round_cohort(&fed, run_seed, round);
            let b = CohortSampler::round_cohort(&fed, run_seed, round);
            prop_assert!(a == b, "cohort must be a pure function of (seed, round)");
            prop_assert!(a.len() == cohort, "cohort size {} != {cohort}", a.len());
            prop_assert!(
                a.windows(2).all(|w| w[0] < w[1]),
                "cohort not sorted/distinct: {a:?}"
            );
            prop_assert!(
                a.iter().all(|&c| (c as usize) < population),
                "client id out of range: {a:?}"
            );
            // the reporting coin is deterministic too, and only the
            // availability model may flip it off
            for &c in a.iter().take(8) {
                let r1 = CohortSampler::reports(&fed, run_seed, round, c);
                let r2 = CohortSampler::reports(&fed, run_seed, round, c);
                prop_assert!(r1 == r2, "reports({c}) must be deterministic");
                if !matches!(fed.sampler, SamplerKind::Availability { .. }) {
                    prop_assert!(r1, "scheduled client {c} must report without availability");
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_uniform_cohorts_cover_a_small_population() {
    check("cohort-coverage", default_cases() / 2, |rng| {
        let cohort = 2 + rng.index(32);
        let population = cohort * 2;
        let mut fed = FederationConfig::new(population, cohort, 1);
        fed.population_seed = rng.next_u64();
        let seed = fed.population_seed;
        let mut seen = std::collections::HashSet::new();
        for round in 0..50u64 {
            for c in CohortSampler::round_cohort(&fed, seed, round) {
                prop_assert!((c as usize) < population, "id {c} out of range");
                seen.insert(c);
            }
        }
        prop_assert!(
            seen.len() == population,
            "50 half-population cohorts must cover everyone: {} of {population}",
            seen.len()
        );
        Ok(())
    });
}
