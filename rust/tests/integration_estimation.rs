//! Integration tests for the statistical-estimation simulator: the
//! empirical content of Theorems 1–2 at test scale.

use rtopk::estimation::{
    bounds, estimate_risk,
    schemes::{keepable, CentralizedScheme, RandomCoordScheme, SubsampleScheme, TruncationScheme},
    Refinement, SparseBernoulli, ThetaPrior,
};
use rtopk::experiments::theory;
use rtopk::util::rng::Rng;

#[test]
fn subsample_scheme_beats_truncation() {
    assert!(theory::subsample_beats_truncation(0xABC));
}

#[test]
fn subsample_beats_random_coordinates_on_sparse_theta() {
    // Random coordinates waste budget on the (d - s) dead coordinates;
    // the paper's scheme only spends bits on the support.
    let model = SparseBernoulli::new(512, 16.0);
    let mut rng = Rng::new(1);
    let sub = SubsampleScheme { preprocess: false };
    let rnd = RandomCoordScheme;
    let (n, k, trials) = (10, 54, 300);
    let a = estimate_risk(&model, &sub, n, k, ThetaPrior::HardSparse, trials, &mut rng);
    let b = estimate_risk(&model, &rnd, n, k, ThetaPrior::HardSparse, trials, &mut rng);
    assert!(
        a.risk < 0.5 * b.risk,
        "subsample {} should crush random coords {}",
        a.risk,
        b.risk
    );
}

#[test]
fn risk_sandwiched_between_theorem_curves() {
    // With generous constants, measured risk of the paper's scheme sits
    // between c * lower and C * upper throughout Theorem 1's k-window.
    let (d, s, n) = (512usize, 32.0f64, 10usize);
    let model = SparseBernoulli::new(d, s);
    let sub = SubsampleScheme { preprocess: false };
    let mut rng = Rng::new(2);
    let (k_lo, k_hi) = bounds::theorem1_k_range(d, s);
    for k in [k_lo.max(20), (k_lo + k_hi) / 2, k_hi] {
        let p = estimate_risk(&model, &sub, n, k, ThetaPrior::HardSparse, 300, &mut rng);
        let up = bounds::theorem1_upper(n, k, d, s, 20.0);
        let lo = bounds::theorem2_lower(n, k, d, s, 0.005);
        assert!(
            p.risk <= up,
            "k={k}: measured {} above generous upper {up}",
            p.risk
        );
        assert!(
            p.risk >= lo,
            "k={k}: measured {} below generous lower {lo}",
            p.risk
        );
    }
}

#[test]
fn centralized_floor_matches_s_over_n_order() {
    // Theorem 2's second term: centralized risk ~ sum_j theta_j (1-theta_j) / n.
    let (d, s) = (256usize, 16.0f64);
    let model = SparseBernoulli::new(d, s);
    let central = CentralizedScheme;
    let mut rng = Rng::new(3);
    for n in [5usize, 20, 80] {
        let p = estimate_risk(&model, &central, n, 0, ThetaPrior::HardSparse, 400, &mut rng);
        // risk should scale ~1/n: compare to s/n within a small factor
        let ref_val = s / n as f64;
        assert!(
            p.risk < ref_val && p.risk > 0.005 * ref_val,
            "n={n}: centralized risk {} vs s/n {ref_val}",
            p.risk
        );
    }
}

#[test]
fn refinements_preserve_scheme_ordering() {
    // §II-C: signs, scaling, and perturbations don't change which scheme
    // wins. (Scaling inflates absolute risk by M^2 for every scheme.)
    let mut rng = Rng::new(4);
    let (d, s, n, k, trials) = (256usize, 16.0f64, 10usize, 80usize, 300usize);
    for (refinement, preprocess) in [
        (Refinement::Plain, false),
        (Refinement::Signed, false),
        (Refinement::Scaled(4.0), false),
        (Refinement::Perturbed(0.45), true),
    ] {
        let model = SparseBernoulli::new(d, s).with_refinement(refinement);
        let sub = SubsampleScheme { preprocess };
        let trunc = TruncationScheme;
        let a = estimate_risk(&model, &sub, n, k, ThetaPrior::HardSparse, trials, &mut rng);
        let b = estimate_risk(&model, &trunc, n, k, ThetaPrior::HardSparse, trials, &mut rng);
        assert!(
            a.risk < b.risk,
            "{refinement:?}: subsample {} should beat truncation {}",
            a.risk,
            b.risk
        );
    }
}

#[test]
fn truncation_bias_persists_as_n_grows() {
    // The defining failure of deterministic truncation on the dense
    // worst-case theta: its risk is bias-dominated, so it does NOT vanish
    // as n grows, while the unbiased subsampling scheme's variance decays
    // ~1/n and overtakes it. (At small n the IPW variance can exceed the
    // truncation bias — the advantage is asymptotic.)
    let model = SparseBernoulli::new(128, 32.0);
    let trunc = TruncationScheme;
    let sub = SubsampleScheme { preprocess: false };
    let mut rng = Rng::new(5);
    let t_small = estimate_risk(&model, &trunc, 10, 60, ThetaPrior::DenseWorstCase, 200, &mut rng);
    let t_large = estimate_risk(&model, &trunc, 100, 60, ThetaPrior::DenseWorstCase, 200, &mut rng);
    let s_small = estimate_risk(&model, &sub, 10, 60, ThetaPrior::DenseWorstCase, 200, &mut rng);
    let s_large = estimate_risk(&model, &sub, 100, 60, ThetaPrior::DenseWorstCase, 200, &mut rng);
    // subsample decays ~1/n
    assert!(
        s_large.risk < 0.25 * s_small.risk,
        "subsample risk should decay ~1/n: {} -> {}",
        s_small.risk,
        s_large.risk
    );
    // truncation barely improves (bias floor)
    assert!(
        t_large.risk > 0.5 * t_small.risk,
        "truncation should be bias-floored: {} -> {}",
        t_small.risk,
        t_large.risk
    );
    // and at large n the ordering is decisively the paper's
    assert!(
        s_large.risk < 0.5 * t_large.risk,
        "n=100: subsample {} vs truncation {}",
        s_large.risk,
        t_large.risk
    );
}

#[test]
fn bit_budget_arithmetic_consistent() {
    // keepable() implements k' >= (k - log2 d)/log2 d from §V step (ii).
    for d in [64usize, 1024, 1 << 16] {
        let logd = (d as f64).log2();
        for k in [2 * logd as usize, 10 * logd as usize, 100 * logd as usize] {
            let kp = keepable(d, k);
            assert!(kp >= 1);
            assert!(
                kp as f64 >= ((k as f64 - logd) / logd).floor().min(1.0),
                "d={d} k={k}"
            );
            // never exceeds the information-theoretic budget
            assert!(
                (kp as f64) * logd <= k as f64 + logd,
                "d={d} k={k} kp={kp} overshoots budget"
            );
        }
    }
}
